//! Collective-algorithm layer: lowering a [`CollectiveKind`] over a
//! device group into a **phased, topology-aware execution plan**.
//!
//! The paper's HTAE owes its accuracy to modeling *how* collectives
//! traverse the Fig. 7 link hierarchy, not just how many bytes they
//! move. This module is that lowering: every collective becomes a
//! [`CollectivePlan`] — an ordered sequence of [`PlanPhase`]s, each a
//! set of concurrent point-to-point [`FlowSpec`]s plus an α
//! latency-step count. Three algorithm families are modeled:
//!
//! - **flat ring** — the NCCL ring schedule over the topology-aware
//!   [`Cluster::ring_order`]; one phase whose segments each carry the
//!   algorithm's bus-traffic volume;
//! - **binomial tree** — log₂-depth reduce + broadcast rounds; fewer α
//!   steps, more bus traffic, so it wins on small (latency-bound)
//!   messages exactly as in NCCL;
//! - **2-level hierarchical** — the NCCL cross-node schedule: per-node
//!   ring reduce-scatter, then per-shard cross-node rings over the
//!   NICs, then per-node ring all-gather. Intra-node phases run at
//!   NVLink/PCIe speed and only `2·bytes·(m-1)/m` per node crosses a
//!   NIC, instead of the flat ring's full serialized volume.
//!
//! [`CollAlgo::Auto`] picks per collective by comparing the plans'
//! closed-form isolated costs (α steps + exact max-min fluid phase
//! times), which makes the size/span cutover emergent rather than a
//! tuned threshold. Both simulators consume the *same* plan: the
//! emulator drives each phase's flows through its fair-share solver
//! (bandwidth sharing then emerges over the op's lifetime), while HTAE
//! uses the closed-form per-phase α–β costs — so on an uncontended
//! group the two agree to float rounding (pinned in
//! `emulator::tests::planned_collectives_agree_between_htae_and_engine`).

use crate::cluster::{Cluster, DeviceId, LinkId};
use crate::compiler::{CollectiveKind, CommTask};
use crate::emulator::fairshare;
use crate::estimator::features::collective_profile;
use crate::util::time::{secs_to_ps, Ps, SEC};

/// Collective lowering algorithm selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollAlgo {
    /// The pre-plan ablation path: one monolithic α–β cost from
    /// [`collective_profile`] / `ring_bus_bandwidth`, flows decomposed
    /// flat (kept for the Fig. 9 style ablation comparisons).
    Monolithic,
    /// Flat ring schedule for everything.
    Ring,
    /// Binomial tree for all-reduce (ring for the sharded collectives).
    Tree,
    /// NCCL-style 2-level hierarchy for cross-node all-reduce (falls
    /// back to ring when the group fits one node or is irregular).
    Hierarchical,
    /// Per-collective argmin over the applicable plans' closed-form
    /// costs (message size and group span decide, as in NCCL's tuner).
    Auto,
}

impl CollAlgo {
    /// CLI / display name.
    pub fn name(self) -> &'static str {
        match self {
            CollAlgo::Monolithic => "mono",
            CollAlgo::Ring => "ring",
            CollAlgo::Tree => "tree",
            CollAlgo::Hierarchical => "hier",
            CollAlgo::Auto => "auto",
        }
    }

    /// Parse a CLI name: `ring | tree | hier | auto`, plus `mono` (the
    /// ablation switch preserving the monolithic path).
    pub fn parse(s: &str) -> Option<CollAlgo> {
        match s {
            "mono" | "monolithic" => Some(CollAlgo::Monolithic),
            "ring" => Some(CollAlgo::Ring),
            "tree" => Some(CollAlgo::Tree),
            "hier" | "hierarchical" => Some(CollAlgo::Hierarchical),
            "auto" => Some(CollAlgo::Auto),
            _ => None,
        }
    }
}

/// Plan-dedup key shared by HTAE and the emulator engines: identical
/// `(kind, group, bytes)` collectives (micro-batch repeats) lower
/// identically; only per-task noise (ripple) differs at launch.
pub type PlanKey = (CollectiveKind, Vec<DeviceId>, u64);

/// Build the [`PlanKey`] of a communication task.
pub fn plan_key(c: &CommTask) -> PlanKey {
    (c.kind, c.group.clone(), c.bytes)
}

/// One point-to-point transfer of a phase (concurrent with its phase
/// siblings).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowSpec {
    /// Sending device.
    pub src: DeviceId,
    /// Receiving device.
    pub dst: DeviceId,
    /// Bytes this flow moves over the phase.
    pub bytes: f64,
}

/// One sequential phase of a collective plan.
#[derive(Debug, Clone)]
pub struct PlanPhase {
    /// Phase label (trace export, debugging): `"ar-ring"`,
    /// `"intra-rs"`, `"reduce-tree"`, ...
    pub label: &'static str,
    /// Latency steps of this phase (α multiplier).
    pub steps: f64,
    /// Per-step latency in [`Ps`] (worst pairwise α among the phase's
    /// transfers).
    pub alpha_ps: Ps,
    /// Concurrent flows of the phase.
    pub flows: Vec<FlowSpec>,
}

impl PlanPhase {
    /// Total α of the phase, ps.
    pub fn alpha_total_ps(&self) -> Ps {
        (self.steps * self.alpha_ps as f64) as Ps
    }

    /// Exact completion time of the phase's flows in isolation under
    /// max-min fair sharing (fluid model), seconds. This is precisely
    /// what the emulator's fair-share engine computes when nothing else
    /// contends, so HTAE's closed-form β and the event engine agree.
    ///
    /// Flow byte counts are clamped to ≥ 1 byte and empty-path flows
    /// complete instantly, mirroring the engines' conventions.
    pub fn fluid_secs(&self, cluster: &Cluster) -> f64 {
        let paths: Vec<Vec<LinkId>> = self
            .flows
            .iter()
            .map(|f| cluster.path(f.src, f.dst))
            .collect();
        let mut rem: Vec<f64> = self.flows.iter().map(|f| f.bytes.max(1.0)).collect();
        let mut live: Vec<usize> = (0..self.flows.len())
            .filter(|&i| !paths[i].is_empty())
            .collect();
        let mut t = 0.0f64;
        while !live.is_empty() {
            let live_paths: Vec<&[LinkId]> = live.iter().map(|&i| paths[i].as_slice()).collect();
            let mut scratch = fairshare::Scratch::new(cluster.links.len());
            let mut rates = Vec::new();
            fairshare::maxmin_rates_into(
                &live_paths,
                cluster.links.len(),
                &|l| cluster.links[l].bandwidth,
                &mut scratch,
                &mut rates,
            );
            let mut dt = f64::INFINITY;
            for (k, &i) in live.iter().enumerate() {
                if rates[k] > 0.0 && rates[k].is_finite() {
                    dt = dt.min(rem[i] / rates[k]);
                }
            }
            if !dt.is_finite() {
                break; // no capacity at all: plan degenerates, stop
            }
            t += dt;
            let mut next_live = Vec::with_capacity(live.len());
            for (k, &i) in live.iter().enumerate() {
                rem[i] -= dt * rates[k];
                // The flows that set dt finish now; keep the rest.
                if rem[i] > dt * rates[k].max(1.0) * 1e-12 && rem[i] > 1e-9 {
                    next_live.push(i);
                }
            }
            if next_live.len() == live.len() {
                break; // numeric stall guard (cannot happen with finite dt)
            }
            live = next_live;
        }
        t
    }
}

/// A lowered collective: sequential phases of concurrent flows.
#[derive(Debug, Clone)]
pub struct CollectivePlan {
    /// The concrete algorithm the plan uses (`"ring"`, `"tree"`,
    /// `"hier"`, never `"auto"`).
    pub algo: &'static str,
    /// Sequential phases. Always non-empty; degenerate groups get one
    /// flow-less phase.
    pub phases: Vec<PlanPhase>,
}

impl CollectivePlan {
    /// Total latency term: Σ steps × per-step α, ps.
    pub fn alpha_ps(&self) -> Ps {
        self.phases.iter().map(|p| p.alpha_total_ps()).sum()
    }

    /// Total bandwidth term: Σ per-phase isolated fluid times, ps.
    pub fn beta_ps(&self, cluster: &Cluster) -> Ps {
        secs_to_ps(self.phases.iter().map(|p| p.fluid_secs(cluster)).sum())
    }

    /// Closed-form isolated cost (α + β), ps.
    pub fn cost_ps(&self, cluster: &Cluster) -> Ps {
        self.alpha_ps() + self.beta_ps(cluster)
    }

    /// Per-phase `(label, α, β)` breakdown, ps (trace sub-spans).
    pub fn phase_costs(&self, cluster: &Cluster) -> Vec<(&'static str, Ps, Ps)> {
        self.phases
            .iter()
            .map(|p| {
                (
                    p.label,
                    p.alpha_total_ps(),
                    secs_to_ps(p.fluid_secs(cluster)),
                )
            })
            .collect()
    }
}

/// Lower a communication task to its plan under `algo`.
/// `CollAlgo::Monolithic` is not a plan — callers keep the legacy α–β
/// path for it; passing it here falls back to the ring plan.
pub fn lower(cluster: &Cluster, algo: CollAlgo, c: &CommTask) -> CollectivePlan {
    let bytes = c.bytes as f64;
    match c.kind {
        CollectiveKind::P2p => p2p_plan(cluster, &c.group, bytes),
        CollectiveKind::Broadcast => broadcast_plan(cluster, &c.group, bytes),
        CollectiveKind::AllToAll => match algo {
            CollAlgo::Hierarchical => all_to_all_hier(cluster, &c.group, bytes)
                .unwrap_or_else(|| all_to_all_plan(cluster, &c.group, bytes)),
            CollAlgo::Auto => {
                let flat = all_to_all_plan(cluster, &c.group, bytes);
                match all_to_all_hier(cluster, &c.group, bytes) {
                    Some(h) if h.cost_ps(cluster) < flat.cost_ps(cluster) => h,
                    _ => flat,
                }
            }
            _ => all_to_all_plan(cluster, &c.group, bytes),
        },
        CollectiveKind::AllGather => ring_plan(cluster, &c.group, bytes, "ag-ring", 1.0),
        CollectiveKind::ReduceScatter => ring_plan(cluster, &c.group, bytes, "rs-ring", 1.0),
        CollectiveKind::AllReduce => match algo {
            CollAlgo::Ring | CollAlgo::Monolithic => allreduce_ring(cluster, &c.group, bytes),
            CollAlgo::Tree => allreduce_tree(cluster, &c.group, bytes),
            CollAlgo::Hierarchical => allreduce_hier(cluster, &c.group, bytes)
                .unwrap_or_else(|| allreduce_ring(cluster, &c.group, bytes)),
            CollAlgo::Auto => {
                let mut best = allreduce_ring(cluster, &c.group, bytes);
                let mut best_cost = best.cost_ps(cluster);
                for cand in [
                    Some(allreduce_tree(cluster, &c.group, bytes)),
                    allreduce_hier(cluster, &c.group, bytes),
                ]
                .into_iter()
                .flatten()
                {
                    let cost = cand.cost_ps(cluster);
                    if cost < best_cost {
                        best = cand;
                        best_cost = cost;
                    }
                }
                best
            }
        },
    }
}

/// Worst pairwise α over a flow set, ps.
fn max_flow_alpha(cluster: &Cluster, flows: &[FlowSpec]) -> Ps {
    flows
        .iter()
        .map(|f| cluster.pair_latency(f.src, f.dst))
        .max()
        .unwrap_or(0)
}

/// Ring neighbor segments over `ring`, each carrying `vol` bytes. A
/// 2-rank "ring" is a single full-duplex exchange: its two wrap-around
/// segments traverse the same duplex links, so only one flow is
/// emitted (see `Cluster::ring_bus_bandwidth`).
fn ring_segments(ring: &[DeviceId], vol: f64) -> Vec<FlowSpec> {
    if ring.len() < 2 {
        return Vec::new();
    }
    let n = if ring.len() == 2 { 1 } else { ring.len() };
    (0..n)
        .map(|i| FlowSpec {
            src: ring[i],
            dst: ring[(i + 1) % ring.len()],
            bytes: vol,
        })
        .collect()
}

/// Single ring phase moving `traffic_scale × bytes × (n-1)/n` per
/// segment with `scale_steps × (n-1)` latency steps (all-gather /
/// reduce-scatter use 1, all-reduce uses 2).
fn ring_plan(
    cluster: &Cluster,
    group: &[DeviceId],
    bytes: f64,
    label: &'static str,
    scale_steps: f64,
) -> CollectivePlan {
    let n = group.len();
    if n < 2 {
        return degenerate_plan("ring");
    }
    let ring = cluster.ring_order(group);
    let vol = bytes * scale_steps * (n as f64 - 1.0) / n as f64;
    let flows = ring_segments(&ring, vol);
    CollectivePlan {
        algo: "ring",
        phases: vec![PlanPhase {
            label,
            steps: scale_steps * (n as f64 - 1.0),
            alpha_ps: cluster.ring_latency(group),
            flows,
        }],
    }
}

/// Flow-less plan for 1-rank groups and empty payloads.
fn degenerate_plan(algo: &'static str) -> CollectivePlan {
    CollectivePlan {
        algo,
        phases: vec![PlanPhase {
            label: "noop",
            steps: 0.0,
            alpha_ps: 0,
            flows: Vec::new(),
        }],
    }
}

fn p2p_plan(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> CollectivePlan {
    if group.len() < 2 || group[0] == group[1] {
        return degenerate_plan("ring");
    }
    CollectivePlan {
        algo: "ring",
        phases: vec![PlanPhase {
            label: "p2p",
            steps: 1.0,
            alpha_ps: cluster.pair_latency(group[0], group[1]),
            flows: vec![FlowSpec {
                src: group[0],
                dst: group[1],
                bytes,
            }],
        }],
    }
}

/// Broadcast always lowers to binomial-tree rounds from the root
/// (`group[0]`): each round doubles the holder set.
fn broadcast_plan(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> CollectivePlan {
    let n = group.len();
    if n < 2 {
        return degenerate_plan("tree");
    }
    let mut phases = Vec::new();
    let mut holders = 1usize;
    while holders < n {
        let flows: Vec<FlowSpec> = (holders..(2 * holders).min(n))
            .map(|i| FlowSpec {
                src: group[i - holders],
                dst: group[i],
                bytes,
            })
            .collect();
        phases.push(PlanPhase {
            label: "bcast-tree",
            steps: 1.0,
            alpha_ps: max_flow_alpha(cluster, &flows),
            flows,
        });
        holders *= 2;
    }
    CollectivePlan {
        algo: "tree",
        phases,
    }
}

/// All-to-all: a single phase of the full pair mesh, `bytes/n` per
/// pair, `n-1` latency steps.
fn all_to_all_plan(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> CollectivePlan {
    let n = group.len();
    if n < 2 {
        return degenerate_plan("ring");
    }
    let per = bytes / n as f64;
    let mut flows = Vec::with_capacity(n * (n - 1));
    for &a in group {
        for &b in group {
            if a != b {
                flows.push(FlowSpec {
                    src: a,
                    dst: b,
                    bytes: per,
                });
            }
        }
    }
    CollectivePlan {
        algo: "ring",
        phases: vec![PlanPhase {
            label: "a2a-mesh",
            steps: n as f64 - 1.0,
            alpha_ps: cluster.ring_latency(group),
            flows,
        }],
    }
}

fn allreduce_ring(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> CollectivePlan {
    ring_plan(cluster, group, bytes, "ar-ring", 2.0)
}

/// Binomial-tree all-reduce: log₂-depth reduce rounds toward
/// `ring_order(group)[0]`, then the mirrored broadcast rounds. Full
/// payload every round — latency-optimal, bandwidth-heavy.
fn allreduce_tree(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> CollectivePlan {
    let n = group.len();
    if n < 2 {
        return degenerate_plan("tree");
    }
    let g = cluster.ring_order(group);
    let mut reduce: Vec<PlanPhase> = Vec::new();
    let mut stride = 1usize;
    while stride < n {
        let mut flows = Vec::new();
        let mut i = 0;
        while i + stride < n {
            flows.push(FlowSpec {
                src: g[i + stride],
                dst: g[i],
                bytes,
            });
            i += 2 * stride;
        }
        reduce.push(PlanPhase {
            label: "reduce-tree",
            steps: 1.0,
            alpha_ps: max_flow_alpha(cluster, &flows),
            flows,
        });
        stride *= 2;
    }
    let mut phases = reduce.clone();
    for p in reduce.iter().rev() {
        phases.push(PlanPhase {
            label: "bcast-tree",
            steps: 1.0,
            alpha_ps: p.alpha_ps,
            flows: p
                .flows
                .iter()
                .map(|f| FlowSpec {
                    src: f.dst,
                    dst: f.src,
                    bytes,
                })
                .collect(),
        });
    }
    CollectivePlan {
        algo: "tree",
        phases,
    }
}

/// NCCL-style 2-level hierarchical all-reduce. Applicable when the
/// group spans ≥ 2 nodes with the same member count `k ≥ 1` per node:
///
/// 1. `intra-rs` — per-node ring reduce-scatter (k ≥ 2 only), leaving
///    each local rank with a `bytes/k` shard of partial sums;
/// 2. `inter-ar` — `k` concurrent cross-node rings (one per local
///    shard index) all-reducing `bytes/k` over the NICs;
/// 3. `intra-ag` — per-node ring all-gather mirroring phase 1.
///
/// Irregular groups return `None` (callers fall back to the flat
/// ring).
fn allreduce_hier(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> Option<CollectivePlan> {
    if group.len() < 2 {
        return None;
    }
    let (nodes, k) = node_groups(cluster, group)?;
    let m = nodes.len();
    let mut phases = Vec::new();
    if k >= 2 {
        // Phase 1: concurrent per-node reduce-scatters.
        let vol = bytes * (k as f64 - 1.0) / k as f64;
        let mut flows = Vec::new();
        for mem in &nodes {
            flows.extend(ring_segments(mem, vol));
        }
        phases.push(PlanPhase {
            label: "intra-rs",
            steps: k as f64 - 1.0,
            alpha_ps: max_flow_alpha(cluster, &flows),
            flows,
        });
    }
    // Phase 2: k concurrent cross-node rings over shard j.
    let shard = bytes / k as f64;
    let vol = shard * 2.0 * (m as f64 - 1.0) / m as f64;
    let mut flows = Vec::new();
    for j in 0..k {
        let cross: Vec<DeviceId> = nodes.iter().map(|mem| mem[j]).collect();
        flows.extend(ring_segments(&cross, vol));
    }
    phases.push(PlanPhase {
        label: "inter-ar",
        steps: 2.0 * (m as f64 - 1.0),
        alpha_ps: max_flow_alpha(cluster, &flows),
        flows,
    });
    if k >= 2 {
        // Phase 3: concurrent per-node all-gathers (mirror of phase 1).
        let vol = bytes * (k as f64 - 1.0) / k as f64;
        let mut flows = Vec::new();
        for mem in &nodes {
            flows.extend(ring_segments(mem, vol));
        }
        phases.push(PlanPhase {
            label: "intra-ag",
            steps: k as f64 - 1.0,
            alpha_ps: max_flow_alpha(cluster, &flows),
            flows,
        });
    }
    Some(CollectivePlan {
        algo: "hier",
        phases,
    })
}

/// Node-major member lists of `group` (via [`Cluster::ring_order`] +
/// [`Cluster::node_of`]): `Some((members_per_node, k))` when the group
/// spans ≥ 2 nodes with the same member count `k` per node; irregular
/// or single-node groups return `None` (callers fall back to flat).
fn node_groups(cluster: &Cluster, group: &[DeviceId]) -> Option<(Vec<Vec<DeviceId>>, usize)> {
    let ring = cluster.ring_order(group);
    let mut nodes: Vec<(usize, Vec<DeviceId>)> = Vec::new();
    for &d in &ring {
        let nd = cluster.node_of(d);
        match nodes.last_mut() {
            Some((last, members)) if *last == nd => members.push(d),
            _ => nodes.push((nd, vec![d])),
        }
    }
    if nodes.len() < 2 {
        return None;
    }
    let k = nodes[0].1.len();
    if nodes.iter().any(|(_, mem)| mem.len() != k) {
        return None;
    }
    Some((nodes.into_iter().map(|(_, mem)| mem).collect(), k))
}

/// 2-level hierarchical all-to-all (the expert-parallel dispatch /
/// combine path). All-to-all volume is irreducible — every byte has
/// exactly one destination — so unlike [`allreduce_hier`] this saves no
/// NIC traffic; it wins on *latency*: `(k-1) + (m-1)` α steps (the
/// intra ones at NVLink α) instead of the flat mesh's `n-1` at the
/// worst cross-node α. Small, latency-bound payloads — the
/// per-micro-batch MoE dispatch pattern — cross over in its favor;
/// [`CollAlgo::Auto`] decides per message from the closed-form costs.
///
/// 1. `a2a-intra` (k ≥ 2 only) — per-node full mesh: each rank hands
///    each local peer the `bytes/k` slice headed for that peer's rail;
/// 2. `a2a-inter` — `k` concurrent per-rail meshes over the NICs,
///    `bytes/m` per node pair and rail.
fn all_to_all_hier(cluster: &Cluster, group: &[DeviceId], bytes: f64) -> Option<CollectivePlan> {
    if group.len() < 2 {
        return None;
    }
    let (nodes, k) = node_groups(cluster, group)?;
    let m = nodes.len();
    let mut phases = Vec::new();
    if k >= 2 {
        let per = bytes / k as f64;
        let mut flows = Vec::new();
        for mem in &nodes {
            for &a in mem {
                for &b in mem {
                    if a != b {
                        flows.push(FlowSpec {
                            src: a,
                            dst: b,
                            bytes: per,
                        });
                    }
                }
            }
        }
        phases.push(PlanPhase {
            label: "a2a-intra",
            steps: k as f64 - 1.0,
            alpha_ps: max_flow_alpha(cluster, &flows),
            flows,
        });
    }
    let per = bytes / m as f64;
    let mut flows = Vec::new();
    for j in 0..k {
        for a in 0..m {
            for b in 0..m {
                if a != b {
                    flows.push(FlowSpec {
                        src: nodes[a][j],
                        dst: nodes[b][j],
                        bytes: per,
                    });
                }
            }
        }
    }
    phases.push(PlanPhase {
        label: "a2a-inter",
        steps: m as f64 - 1.0,
        alpha_ps: max_flow_alpha(cluster, &flows),
        flows,
    });
    Some(CollectivePlan {
        algo: "hier",
        phases,
    })
}

/// The monolithic (pre-plan) closed-form cost of a collective, ps —
/// the ablation path: `steps × α + factor × bytes / ring_bus_bw`. This
/// mirrors `estimator::features::comm_row` + `cost_ns` in f64 and is
/// used by tests comparing plans against the flat model.
pub fn monolithic_cost_ps(cluster: &Cluster, c: &CommTask) -> Ps {
    let n = c.group.len();
    if n < 2 {
        return 0; // degenerate group: nothing traverses a link
    }
    let (steps, factor) = collective_profile(c.kind, n);
    let (bus_bw, alpha_ps) = match c.kind {
        CollectiveKind::P2p => (
            cluster.pair_bandwidth(c.group[0], c.group[1]),
            cluster.pair_latency(c.group[0], c.group[1]),
        ),
        _ => (
            cluster.ring_bus_bandwidth(&c.group),
            cluster.ring_latency(&c.group),
        ),
    };
    let beta = if bus_bw.is_finite() && bus_bw > 0.0 {
        (c.bytes as f64 * factor / bus_bw * SEC as f64) as Ps
    } else {
        0
    };
    (steps * alpha_ps as f64) as Ps + beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Preset;
    use crate::compiler::CommClass;

    fn ar(group: Vec<DeviceId>, bytes: u64) -> CommTask {
        CommTask {
            kind: CollectiveKind::AllReduce,
            group,
            bytes,
            class: CommClass::Gradient,
        }
    }

    #[test]
    fn ring_plan_matches_monolithic_closed_form() {
        // The flat ring plan's fluid β equals traffic / ring_bus_bw, so
        // planned ring and the legacy monolithic cost agree.
        let c = Cluster::preset(Preset::HC2, 1);
        for group in [vec![0usize, 1, 2, 3], (0..8).collect::<Vec<_>>()] {
            let t = ar(group, 1 << 24);
            let plan = lower(&c, CollAlgo::Ring, &t);
            let planned = plan.cost_ps(&c) as f64;
            let mono = monolithic_cost_ps(&c, &t) as f64;
            let rel = (planned - mono).abs() / mono;
            assert!(rel < 1e-6, "ring plan {planned} vs monolithic {mono}");
        }
    }

    #[test]
    fn hierarchical_beats_flat_ring_across_nodes() {
        // The tentpole acceptance: on a cross-node group the 2-level
        // plan undercuts the flat ring, which serializes the whole
        // volume through the NIC bottleneck.
        let c = Cluster::preset(Preset::HC2, 2);
        let t = ar((0..16).collect(), 64 << 20);
        let ring = allreduce_ring(&c, &t.group, t.bytes as f64);
        let hier = allreduce_hier(&c, &t.group, t.bytes as f64).expect("regular group");
        let rc = ring.cost_ps(&c);
        let hc = hier.cost_ps(&c);
        assert!(
            hc < rc,
            "hierarchical {hc} ps must beat flat ring {rc} ps cross-node"
        );
        // And auto must therefore not pick ring here.
        let auto = lower(&c, CollAlgo::Auto, &t);
        assert_eq!(auto.algo, "hier");
        assert_eq!(auto.cost_ps(&c), hc);
    }

    #[test]
    fn tree_wins_small_messages_ring_wins_large() {
        let c = Cluster::preset(Preset::HC2, 1);
        let small = lower(&c, CollAlgo::Auto, &ar((0..8).collect(), 1 << 10));
        assert_eq!(small.algo, "tree", "1 KiB all-reduce is latency-bound");
        let large = lower(&c, CollAlgo::Auto, &ar((0..8).collect(), 64 << 20));
        assert_eq!(large.algo, "ring", "64 MiB all-reduce is bandwidth-bound");
    }

    #[test]
    fn hier_not_applicable_single_node_or_irregular() {
        let c = Cluster::preset(Preset::HC2, 2);
        assert!(allreduce_hier(&c, &[0, 1, 2, 3], 1e6).is_none(), "one node");
        assert!(
            allreduce_hier(&c, &[0, 1, 8], 1e6).is_none(),
            "irregular per-node counts"
        );
        // Forcing hier on an inapplicable group falls back to ring.
        let t = ar(vec![0, 1, 2, 3], 1 << 20);
        let plan = lower(&c, CollAlgo::Hierarchical, &t);
        assert_eq!(plan.algo, "ring");
    }

    #[test]
    fn hier_phase_structure_and_volume() {
        let c = Cluster::preset(Preset::HC2, 2);
        let bytes = 16.0 * 1024.0 * 1024.0;
        let plan = allreduce_hier(&c, &(0..16).collect::<Vec<_>>(), bytes).unwrap();
        let labels: Vec<&str> = plan.phases.iter().map(|p| p.label).collect();
        assert_eq!(labels, ["intra-rs", "inter-ar", "intra-ag"]);
        // Phase 2: k=8 cross rings of 2 nodes → 8 single-flow duplex
        // exchanges of bytes/8 each (2(m-1)/m = 1 at m=2).
        let inter = &plan.phases[1];
        assert_eq!(inter.flows.len(), 8);
        for f in &inter.flows {
            assert!((f.bytes - bytes / 8.0).abs() < 1e-6);
            assert_ne!(c.node_of(f.src), c.node_of(f.dst));
        }
        // Intra phases stay on-node.
        for p in [&plan.phases[0], &plan.phases[2]] {
            for f in &p.flows {
                assert_eq!(c.node_of(f.src), c.node_of(f.dst));
            }
        }
    }

    #[test]
    fn one_rank_per_node_skips_intra_phases() {
        let c = Cluster::preset(Preset::HC2, 4);
        let plan = allreduce_hier(&c, &[0, 8, 16, 24], 1e6).unwrap();
        assert_eq!(plan.phases.len(), 1);
        assert_eq!(plan.phases[0].label, "inter-ar");
    }

    fn a2a(group: Vec<DeviceId>, bytes: u64) -> CommTask {
        CommTask {
            kind: CollectiveKind::AllToAll,
            group,
            bytes,
            class: CommClass::Feature,
        }
    }

    #[test]
    fn hier_a2a_beats_flat_mesh_on_small_cross_node_payloads() {
        // EP dispatch/combine: 256 KiB over 2 nodes is latency-bound, so
        // the (k-1)+(m-1)-step hierarchical schedule undercuts the flat
        // mesh's n-1 steps at cross-node α — and Auto must pick it.
        let c = Cluster::preset(Preset::HC2, 2);
        let t = a2a((0..16).collect(), 256 << 10);
        let flat = all_to_all_plan(&c, &t.group, t.bytes as f64);
        let hier = all_to_all_hier(&c, &t.group, t.bytes as f64).expect("regular group");
        assert!(
            hier.cost_ps(&c) < flat.cost_ps(&c),
            "hier {} ps must beat flat {} ps at 256 KiB cross-node",
            hier.cost_ps(&c),
            flat.cost_ps(&c)
        );
        let auto = lower(&c, CollAlgo::Auto, &t);
        assert_eq!(auto.algo, "hier");
        // Large payloads are bandwidth-bound and phases serialize, so
        // the flat mesh wins back.
        let big = lower(&c, CollAlgo::Auto, &a2a((0..16).collect(), 256 << 20));
        assert_eq!(big.algo, "ring");
    }

    #[test]
    fn hier_a2a_structure_and_volume() {
        let c = Cluster::preset(Preset::HC2, 2);
        let bytes = 1024.0 * 1024.0;
        let plan = all_to_all_hier(&c, &(0..16).collect::<Vec<_>>(), bytes).unwrap();
        let labels: Vec<&str> = plan.phases.iter().map(|p| p.label).collect();
        assert_eq!(labels, ["a2a-intra", "a2a-inter"]);
        // Intra: per-node full mesh, k(k-1)=56 flows per node of bytes/8.
        let intra = &plan.phases[0];
        assert_eq!(intra.flows.len(), 2 * 8 * 7);
        for f in &intra.flows {
            assert_eq!(c.node_of(f.src), c.node_of(f.dst));
            assert!((f.bytes - bytes / 8.0).abs() < 1e-6);
        }
        // Inter: 8 rails × m(m-1)=2 directed pairs of bytes/2 — the
        // node-to-node volume k·(m-1)·bytes/m matches the flat mesh's
        // (volume is irreducible for all-to-all).
        let inter = &plan.phases[1];
        assert_eq!(inter.flows.len(), 8 * 2);
        for f in &inter.flows {
            assert_ne!(c.node_of(f.src), c.node_of(f.dst));
            assert!((f.bytes - bytes / 2.0).abs() < 1e-6);
        }
        // Single-node groups have no hierarchy to exploit.
        let single = Cluster::preset(Preset::HC2, 1);
        assert!(all_to_all_hier(&single, &(0..8).collect::<Vec<_>>(), bytes).is_none());
        // Forcing hier on one falls back to the flat mesh.
        let plan = lower(&single, CollAlgo::Hierarchical, &a2a((0..8).collect(), 1 << 20));
        assert_eq!(plan.algo, "ring");
        assert_eq!(plan.phases[0].label, "a2a-mesh");
    }

    #[test]
    fn one_rank_per_node_a2a_skips_the_intra_phase() {
        let c = Cluster::preset(Preset::HC2, 4);
        let plan = all_to_all_hier(&c, &[0, 8, 16, 24], 1e6).unwrap();
        assert_eq!(plan.phases.len(), 1);
        assert_eq!(plan.phases[0].label, "a2a-inter");
        assert_eq!(plan.phases[0].flows.len(), 4 * 3);
    }

    #[test]
    fn two_rank_ring_is_a_single_duplex_exchange() {
        let c = Cluster::preset(Preset::HC2, 1);
        let plan = lower(&c, CollAlgo::Ring, &ar(vec![0, 1], 1 << 20));
        assert_eq!(plan.phases.len(), 1);
        assert_eq!(plan.phases[0].flows.len(), 1, "no double-counted wrap");
        // factor 2(n-1)/n = 1 at n=2: the exchange carries `bytes`.
        assert!((plan.phases[0].flows[0].bytes - (1u64 << 20) as f64).abs() < 1e-9);
    }

    #[test]
    fn degenerate_groups_lower_to_noop_plans() {
        let c = Cluster::preset(Preset::HC2, 1);
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
            CollectiveKind::Broadcast,
        ] {
            let t = CommTask {
                kind,
                group: vec![3],
                bytes: 1 << 20,
                class: CommClass::Gradient,
            };
            let plan = lower(&c, CollAlgo::Auto, &t);
            assert_eq!(plan.phases.len(), 1, "{kind:?}");
            assert!(plan.phases[0].flows.is_empty());
            assert_eq!(plan.cost_ps(&c), 0);
            assert_eq!(monolithic_cost_ps(&c, &t), 0, "{kind:?}");
        }
        // P2p with a single rank must not panic in either cost path.
        let p2p = CommTask {
            kind: CollectiveKind::P2p,
            group: vec![3],
            bytes: 1 << 20,
            class: CommClass::Feature,
        };
        assert_eq!(lower(&c, CollAlgo::Auto, &p2p).cost_ps(&c), 0);
        assert_eq!(monolithic_cost_ps(&c, &p2p), 0);
    }

    #[test]
    fn broadcast_tree_rounds_double_holders() {
        let c = Cluster::preset(Preset::HC2, 1);
        let t = CommTask {
            kind: CollectiveKind::Broadcast,
            group: (0..8).collect(),
            bytes: 1 << 20,
            class: CommClass::Feature,
        };
        let plan = lower(&c, CollAlgo::Auto, &t);
        assert_eq!(plan.phases.len(), 3); // log2(8)
        assert_eq!(
            plan.phases.iter().map(|p| p.flows.len()).collect::<Vec<_>>(),
            vec![1, 2, 4]
        );
        // Total α steps match the monolithic profile.
        let (steps, _) = collective_profile(CollectiveKind::Broadcast, 8);
        let total: f64 = plan.phases.iter().map(|p| p.steps).sum();
        assert_eq!(total, steps);
    }

    #[test]
    fn fluid_time_matches_hand_solve_on_shared_bottleneck() {
        // Two same-node pairs share nothing (NVSwitch): phase time =
        // bytes / port_bw, not 2×.
        let c = Cluster::preset(Preset::HC2, 1);
        let phase = PlanPhase {
            label: "x",
            steps: 0.0,
            alpha_ps: 0,
            flows: vec![
                FlowSpec { src: 0, dst: 1, bytes: 150e9 },
                FlowSpec { src: 2, dst: 3, bytes: 150e9 },
            ],
        };
        let t = phase.fluid_secs(&c);
        assert!((t - 1.0).abs() < 1e-9, "disjoint pairs run at port speed: {t}");
        // Same pair twice → halved shares, doubled time.
        let phase2 = PlanPhase {
            label: "x",
            steps: 0.0,
            alpha_ps: 0,
            flows: vec![
                FlowSpec { src: 0, dst: 1, bytes: 150e9 },
                FlowSpec { src: 0, dst: 1, bytes: 150e9 },
            ],
        };
        let t2 = phase2.fluid_secs(&c);
        assert!((t2 - 2.0).abs() < 1e-9, "shared duplex link halves rates: {t2}");
    }

    #[test]
    fn fluid_time_handles_staggered_completions() {
        // Unequal flows on one link: 100 and 300 bytes at cap 100 B/s.
        // Phase: both at 50 B/s for 2 s (100 done), then 300-flow alone
        // at 100 B/s for 2 s → 4 s total.
        let c = Cluster::preset(Preset::HC2, 1);
        let port = 150e9;
        let phase = PlanPhase {
            label: "x",
            steps: 0.0,
            alpha_ps: 0,
            flows: vec![
                FlowSpec { src: 0, dst: 1, bytes: port },
                FlowSpec { src: 0, dst: 1, bytes: 3.0 * port },
            ],
        };
        let t = phase.fluid_secs(&c);
        assert!((t - 4.0).abs() < 1e-9, "staggered fluid completion: {t}");
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for algo in [
            CollAlgo::Monolithic,
            CollAlgo::Ring,
            CollAlgo::Tree,
            CollAlgo::Hierarchical,
            CollAlgo::Auto,
        ] {
            assert_eq!(CollAlgo::parse(algo.name()), Some(algo));
        }
        assert_eq!(CollAlgo::parse("hierarchical"), Some(CollAlgo::Hierarchical));
        assert_eq!(CollAlgo::parse("monolithic"), Some(CollAlgo::Monolithic));
        assert_eq!(CollAlgo::parse("bogus"), None);
    }
}
