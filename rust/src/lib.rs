//! # Proteus-RS
//!
//! A Rust + JAX + Pallas reproduction of **"Proteus: Simulating the
//! Performance of Distributed DNN Training"** (CS.DC 2023).
//!
//! Proteus predicts the training throughput, step time, and memory
//! footprint of a DNN model parallelized with an arbitrary combination of
//! operator-level strategies (data / model / hybrid / general op-shard
//! parallelism, ZeRO-style memory partitioning) and subgraph-level
//! strategies (pipeline parallelism, recomputation) on a described GPU
//! cluster — without running the model on real hardware.
//!
//! The pipeline mirrors the paper:
//!
//! 1. [`graph`] + [`models`]: the DNN is a layer-level computation graph
//!    with forward and backward operators.
//! 2. [`strategy`]: the parallelization strategy is a **strategy tree** —
//!    leaf nodes carry computation/memory configs for operators/tensors,
//!    non-leaf nodes carry schedule configs (micro-batching,
//!    recomputation).
//! 3. [`compiler`]: `(model, tree, cluster)` is compiled into a
//!    **distributed execution graph**: operators and tensors are split
//!    into per-device partitions, collective communication operators are
//!    inferred via *strategy transformation*, and control dependencies
//!    encode the pipeline/recompute schedule.
//! 4. [`estimator`]: per-operator costs come from a roofline compute
//!    model and an α-β collective model. The batched hot path is an AOT
//!    Pallas/XLA artifact executed through [`runtime`] (PJRT); a
//!    bit-faithful pure-Rust mirror backs unit tests. The [`collective`]
//!    layer refines communication costs further: each collective lowers
//!    to a phased, topology-aware plan (ring / binomial tree /
//!    NCCL-style 2-level hierarchy, auto-selected by message size and
//!    group span) that both simulators consume.
//! 5. [`executor`]: **HTAE** (Hierarchical Topo-Aware Executor) simulates
//!    the schedule, detects *comp-comm overlap* and *bandwidth sharing*
//!    at runtime, adapts operator costs, tracks memory, and reports
//!    throughput/OOM.
//! 6. [`emulator`]: a strictly finer-grained flow-level emulator stands in
//!    for the paper's physical testbed (ground truth) — see DESIGN.md §3.
//! 7. [`baselines`]: FlexFlow-Sim and a Paleo-style analytical model for
//!    the paper's comparisons.
//!
//! ## Quickstart
//!
//! ```no_run
//! use proteus::prelude::*;
//!
//! let model = proteus::models::gpt2(proteus::models::GptConfig::gpt2_117m(), 8);
//! let cluster = Cluster::preset(Preset::HC2, 1);
//! let mut tree = StrategyTree::from_model(&model);
//! tree.assign_data_parallel(&model, cluster.num_devices()).unwrap();
//! let exec = compile(&model, &tree, &cluster).unwrap();
//! let est = OpEstimator::analytical(&cluster);
//! let report = Htae::new(&cluster, &est).simulate(&exec).unwrap();
//! println!("throughput: {:.1} samples/s", report.throughput);
//! ```
//!
//! ## Scenario sweeps
//!
//! [`runtime::SweepRunner`] simulates batches of `(model, cluster,
//! strategy)` scenarios in parallel and ranks them by predicted
//! throughput — the engine behind `proteus sweep` and
//! `examples/strategy_search.rs`:
//!
//! ```no_run
//! use proteus::runtime::{candidate_grid, Scenario, SweepRunner};
//! use proteus::cluster::Preset;
//! use proteus::models::{ModelKind, ModelSpec};
//!
//! let specs = candidate_grid(16, 64);
//! let scenarios: Vec<Scenario> = specs
//!     .into_iter()
//!     .map(|spec| Scenario {
//!         model: ModelSpec::preset(ModelKind::Gpt2),
//!         batch: 64,
//!         preset: Preset::HC2,
//!         nodes: 2,
//!         spec,
//!     })
//!     .collect();
//! let outcomes = SweepRunner::new().run(&scenarios);
//! for o in SweepRunner::rank(&outcomes).iter().take(5) {
//!     println!("{}", o.describe());
//! }
//! ```

#![warn(missing_docs)]

pub mod baselines;
pub mod cli;
pub mod collective;
pub mod harness;
pub mod cluster;
pub mod compiler;
pub mod emulator;
pub mod estimator;
pub mod executor;
pub mod graph;
pub mod models;
pub mod runtime;
pub mod session;
pub mod strategy;
pub mod testing;
pub mod trace;
pub mod util;

/// Convenience re-exports covering the common simulation pipeline.
pub mod prelude {
    pub use crate::baselines::FlexFlowSim;
    pub use crate::cluster::{Cluster, Preset};
    pub use crate::collective::{CollAlgo, CollectivePlan};
    pub use crate::compiler::{compile, ExecGraph};
    pub use crate::emulator::{Emulator, EmulatorConfig};
    pub use crate::estimator::OpEstimator;
    pub use crate::executor::{Htae, HtaeConfig, SimReport};
    pub use crate::graph::{Graph, OpKind};
    pub use crate::models::{ModelKind, ModelSpec};
    pub use crate::runtime::{
        candidate_grid, candidate_grid_with_schedules, dedupe_specs, Scenario, SearchConfig,
        SearchPoint, Searcher, SweepOutcome, SweepRunner,
    };
    pub use crate::session::{SearchRequest, Session, SimulateRequest, SweepRequest};
    pub use crate::strategy::{
        build_strategy, NonUniformSpec, ParallelConfig, PipelineSchedule, ScheduleConfig,
        StageSpec, StrategySpec, StrategyTree,
    };
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
///
/// `Display`/`Error` are implemented by hand: the crate is std-only so
/// it builds in fully offline environments (no `thiserror`).
#[derive(Debug)]
pub enum Error {
    /// Strategy is structurally invalid (bad partition degrees, device
    /// mapping mismatch, unknown node path, ...).
    InvalidStrategy(String),
    /// Execution graph compilation failed.
    Compile(String),
    /// Simulation failed (deadlock, inconsistent graph, ...).
    Simulation(String),
    /// Cluster topology is invalid.
    InvalidCluster(String),
    /// Configuration file / JSON error.
    Config(String),
    /// PJRT runtime error (artifact loading / execution).
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidStrategy(m) => write!(f, "invalid strategy: {m}"),
            Error::Compile(m) => write!(f, "compile error: {m}"),
            Error::Simulation(m) => write!(f, "simulation error: {m}"),
            Error::InvalidCluster(m) => write!(f, "invalid cluster: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used pervasively in the compiler.
    pub fn compile(msg: impl Into<String>) -> Self {
        Error::Compile(msg.into())
    }
    /// Shorthand constructor for simulation errors.
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Simulation(msg.into())
    }
}
