//! # Proteus-RS
//!
//! A Rust + JAX + Pallas reproduction of **"Proteus: Simulating the
//! Performance of Distributed DNN Training"** (CS.DC 2023).
//!
//! Proteus predicts the training throughput, step time, and memory
//! footprint of a DNN model parallelized with an arbitrary combination of
//! operator-level strategies (data / model / hybrid / general op-shard
//! parallelism, ZeRO-style memory partitioning) and subgraph-level
//! strategies (pipeline parallelism, recomputation) on a described GPU
//! cluster — without running the model on real hardware.
//!
//! The pipeline mirrors the paper:
//!
//! 1. [`graph`] + [`models`]: the DNN is a layer-level computation graph
//!    with forward and backward operators.
//! 2. [`strategy`]: the parallelization strategy is a **strategy tree** —
//!    leaf nodes carry computation/memory configs for operators/tensors,
//!    non-leaf nodes carry schedule configs (micro-batching,
//!    recomputation).
//! 3. [`compiler`]: `(model, tree, cluster)` is compiled into a
//!    **distributed execution graph**: operators and tensors are split
//!    into per-device partitions, collective communication operators are
//!    inferred via *strategy transformation*, and control dependencies
//!    encode the pipeline/recompute schedule.
//! 4. [`estimator`]: per-operator costs come from a roofline compute
//!    model and an α-β collective model. The batched hot path is an AOT
//!    Pallas/XLA artifact executed through [`runtime`] (PJRT); a
//!    bit-faithful pure-Rust mirror backs unit tests.
//! 5. [`executor`]: **HTAE** (Hierarchical Topo-Aware Executor) simulates
//!    the schedule, detects *comp-comm overlap* and *bandwidth sharing*
//!    at runtime, adapts operator costs, tracks memory, and reports
//!    throughput/OOM.
//! 6. [`emulator`]: a strictly finer-grained flow-level emulator stands in
//!    for the paper's physical testbed (ground truth) — see DESIGN.md §3.
//! 7. [`baselines`]: FlexFlow-Sim and a Paleo-style analytical model for
//!    the paper's comparisons.
//!
//! ## Quickstart
//!
//! ```no_run
//! use proteus::prelude::*;
//!
//! let model = proteus::models::gpt2(proteus::models::GptConfig::gpt2_117m(), 8);
//! let cluster = Cluster::preset(Preset::HC2, 1);
//! let mut tree = StrategyTree::from_model(&model);
//! tree.assign_data_parallel(&model, cluster.num_devices()).unwrap();
//! let exec = compile(&model, &tree, &cluster).unwrap();
//! let est = OpEstimator::analytical(&cluster);
//! let report = Htae::new(&cluster, &est).simulate(&exec).unwrap();
//! println!("throughput: {:.1} samples/s", report.throughput);
//! ```

pub mod baselines;
pub mod cli;
pub mod harness;
pub mod cluster;
pub mod compiler;
pub mod emulator;
pub mod estimator;
pub mod executor;
pub mod graph;
pub mod models;
pub mod runtime;
pub mod strategy;
pub mod testing;
pub mod trace;
pub mod util;

/// Convenience re-exports covering the common simulation pipeline.
pub mod prelude {
    pub use crate::baselines::FlexFlowSim;
    pub use crate::cluster::{Cluster, Preset};
    pub use crate::compiler::{compile, ExecGraph};
    pub use crate::emulator::{Emulator, EmulatorConfig};
    pub use crate::estimator::OpEstimator;
    pub use crate::executor::{Htae, HtaeConfig, SimReport};
    pub use crate::graph::{Graph, OpKind};
    pub use crate::models::ModelKind;
    pub use crate::strategy::{
        build_strategy, ParallelConfig, ScheduleConfig, StrategySpec, StrategyTree,
    };
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Strategy is structurally invalid (bad partition degrees, device
    /// mapping mismatch, unknown node path, ...).
    #[error("invalid strategy: {0}")]
    InvalidStrategy(String),
    /// Execution graph compilation failed.
    #[error("compile error: {0}")]
    Compile(String),
    /// Simulation failed (deadlock, inconsistent graph, ...).
    #[error("simulation error: {0}")]
    Simulation(String),
    /// Cluster topology is invalid.
    #[error("invalid cluster: {0}")]
    InvalidCluster(String),
    /// Configuration file / JSON error.
    #[error("config error: {0}")]
    Config(String),
    /// PJRT runtime error (artifact loading / execution).
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor used pervasively in the compiler.
    pub fn compile(msg: impl Into<String>) -> Self {
        Error::Compile(msg.into())
    }
    /// Shorthand constructor for simulation errors.
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Simulation(msg.into())
    }
}
