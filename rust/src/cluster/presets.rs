//! The paper's three hardware configurations (Table III) as cluster
//! presets, plus the device parameter tables behind them.
//!
//! | Config | Nodes | GPUs/node | Intra-node | Inter-node          |
//! |--------|-------|-----------|------------|---------------------|
//! | HC1    | 1     | 8×TitanXp | PCIe       | N/A                 |
//! | HC2    | ≤4    | 8×V100    | NVLink     | 100 Gbps            |
//! | HC3    | ≤2    | 8×A100    | NVLink     | 200 Gbps            |
//! | HC4    | ≤512  | 8×V100    | NVLink     | 8×100 Gbps (rails)  |
//!
//! HC4 extrapolates HC2 to datacenter scale: the same V100 nodes, but
//! with one 100 Gbps NIC *per GPU* wired rail-optimized into a
//! non-blocking fat tree — the symmetry-folding scale target (1k–10k
//! devices). It is not a paper configuration.
//!
//! Absolute numbers are public datasheet values; the reproduction's
//! claims are about *relative* prediction error against the ground-truth
//! emulator, which shares these parameters (DESIGN.md §3).

use super::{Cluster, ClusterSpec, DeviceSpec};
use crate::util::time::US;

/// The paper's hardware configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Preset {
    /// 1 node × 8 TitanXp over a two-socket PCIe tree.
    HC1,
    /// Up to 4 nodes × 8 V100 with NVLink and 100 Gbps interconnect.
    HC2,
    /// Up to 2 nodes × 8 A100 with NVLink and 200 Gbps interconnect.
    HC3,
    /// Up to 512 nodes × 8 V100 with NVLink and 8 rail-optimized
    /// 100 Gbps NICs per node (scale-extrapolation config, not from
    /// the paper).
    HC4,
}

impl Preset {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Preset> {
        match s.to_ascii_uppercase().as_str() {
            "HC1" => Some(Preset::HC1),
            "HC2" => Some(Preset::HC2),
            "HC3" => Some(Preset::HC3),
            "HC4" => Some(Preset::HC4),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Preset::HC1 => "HC1",
            Preset::HC2 => "HC2",
            Preset::HC3 => "HC3",
            Preset::HC4 => "HC4",
        }
    }

    /// Maximum node count evaluated in the paper (HC4: the scale
    /// target of the symmetry-folding experiments).
    pub fn max_nodes(self) -> usize {
        match self {
            Preset::HC1 => 1,
            Preset::HC2 => 4,
            Preset::HC3 => 2,
            Preset::HC4 => 512,
        }
    }

    /// All presets.
    pub fn all() -> &'static [Preset] {
        &[Preset::HC1, Preset::HC2, Preset::HC3, Preset::HC4]
    }
}

const GB: f64 = 1e9;

/// TitanXp (Pascal): 12.15 TFLOP/s FP32, 547 GB/s GDDR5X, 12 GB.
pub fn titan_xp() -> DeviceSpec {
    DeviceSpec {
        name: "TitanXp".into(),
        peak_flops: 12.15e12,
        mem_bandwidth: 547.0 * GB,
        memory_bytes: 12 * (1 << 30),
        // PCIe-attached GPUs suffer the most compute/DMA interference.
        overlap_interference: 0.22,
    }
}

/// V100 (Volta): 15.7 TFLOP/s FP32, 900 GB/s HBM2, 16 GB.
pub fn v100() -> DeviceSpec {
    DeviceSpec {
        name: "V100".into(),
        peak_flops: 15.7e12,
        mem_bandwidth: 900.0 * GB,
        memory_bytes: 16 * (1 << 30),
        overlap_interference: 0.12,
    }
}

/// A100 (Ampere): 19.5 TFLOP/s FP32, 1555 GB/s HBM2e, 40 GB.
pub fn a100() -> DeviceSpec {
    DeviceSpec {
        name: "A100".into(),
        peak_flops: 19.5e12,
        mem_bandwidth: 1555.0 * GB,
        memory_bytes: 40 * (1 << 30),
        overlap_interference: 0.08,
    }
}

/// The [`ClusterSpec`] for a preset with `n_nodes` nodes (clamped to the
/// preset's maximum).
pub fn spec(p: Preset, n_nodes: usize) -> ClusterSpec {
    let n_nodes = n_nodes.clamp(1, p.max_nodes());
    match p {
        Preset::HC1 => ClusterSpec {
            name: "HC1".into(),
            n_nodes: 1,
            gpus_per_node: 8,
            device: titan_xp(),
            // Two PCIe switches of 4 GPUs each, one per socket.
            pcie_tree: Some(4),
            // PCIe 3.0 x16 effective.
            port_bandwidth: 13.0 * GB,
            port_latency: 5 * US,
            uplink_bandwidth: 13.0 * GB,
            // QPI between the two sockets.
            qpi_bandwidth: 19.2 * GB,
            nic_bandwidth: 0.0,
            nic_latency: 0,
            nics_per_node: 1,
            oversubscription: 1.0,
        },
        Preset::HC2 => ClusterSpec {
            name: "HC2".into(),
            n_nodes,
            gpus_per_node: 8,
            device: v100(),
            pcie_tree: None,
            // V100 NVLink2: 6 links × 25 GB/s per direction.
            port_bandwidth: 150.0 * GB,
            port_latency: 3 * US,
            uplink_bandwidth: 0.0,
            qpi_bandwidth: 0.0,
            // 100 Gbps ≈ 12.0 GB/s effective.
            nic_bandwidth: 12.0 * GB,
            nic_latency: 8 * US,
            nics_per_node: 1,
            oversubscription: 1.0,
        },
        Preset::HC3 => ClusterSpec {
            name: "HC3".into(),
            n_nodes,
            gpus_per_node: 8,
            device: a100(),
            pcie_tree: None,
            // A100 NVLink3: 12 links × 25 GB/s per direction.
            port_bandwidth: 300.0 * GB,
            port_latency: 3 * US,
            uplink_bandwidth: 0.0,
            qpi_bandwidth: 0.0,
            // 200 Gbps ≈ 24.0 GB/s effective.
            nic_bandwidth: 24.0 * GB,
            nic_latency: 8 * US,
            nics_per_node: 1,
            oversubscription: 1.0,
        },
        Preset::HC4 => ClusterSpec {
            name: "HC4".into(),
            n_nodes,
            gpus_per_node: 8,
            device: v100(),
            pcie_tree: None,
            port_bandwidth: 150.0 * GB,
            port_latency: 3 * US,
            uplink_bandwidth: 0.0,
            qpi_bandwidth: 0.0,
            // One 100 Gbps NIC per GPU, rail-optimized.
            nic_bandwidth: 12.0 * GB,
            nic_latency: 8 * US,
            nics_per_node: 8,
            oversubscription: 1.0,
        },
    }
}

/// Build a preset cluster (infallible: preset specs are valid by
/// construction).
pub fn build(p: Preset, n_nodes: usize) -> Cluster {
    Cluster::from_spec(&spec(p, n_nodes)).expect("preset specs are valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build() {
        for &p in Preset::all() {
            let c = build(p, p.max_nodes());
            assert_eq!(c.gpus_per_node, 8);
            assert!(c.num_devices() >= 8);
        }
    }

    #[test]
    fn node_count_clamps_to_preset_max() {
        let c = build(Preset::HC1, 4);
        assert_eq!(c.n_nodes, 1);
        let c = build(Preset::HC3, 8);
        assert_eq!(c.n_nodes, 2);
    }

    #[test]
    fn parse_roundtrip() {
        for &p in Preset::all() {
            assert_eq!(Preset::parse(p.name()), Some(p));
        }
        assert_eq!(Preset::parse("hc2"), Some(Preset::HC2));
        assert_eq!(Preset::parse("HC9"), None);
    }

    #[test]
    fn faster_generations_have_more_bandwidth() {
        assert!(v100().mem_bandwidth > titan_xp().mem_bandwidth);
        assert!(a100().mem_bandwidth > v100().mem_bandwidth);
        assert!(a100().peak_flops > titan_xp().peak_flops);
    }

    #[test]
    fn interference_decreases_with_generation() {
        assert!(titan_xp().overlap_interference > v100().overlap_interference);
        assert!(v100().overlap_interference > a100().overlap_interference);
    }
}
