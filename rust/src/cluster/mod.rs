//! Cluster topology: devices, physical links, and the link hierarchy used
//! for bandwidth-sharing detection (paper §VI, Fig. 7).
//!
//! Both simulators (HTAE and the ground-truth emulator) and the op
//! estimator share this substrate: a cluster is a set of GPU devices
//! connected by *stateful, shared* physical links. Every device pair has a
//! deterministic link path; communication that traverses the same link
//! competes for its bandwidth.
//!
//! Two intra-node fabrics are modeled, matching the paper's hardware
//! configurations (Table III):
//!
//! - **PCIe tree** (HC1): GPUs hang off PCIe switches, one switch per CPU
//!   socket, sockets joined by QPI.
//! - **NVLink/NVSwitch** (HC2, HC3): each GPU has a high-bandwidth port
//!   into a non-blocking switch fabric.
//!
//! Inter-node traffic goes through per-node NICs into the cluster
//! fabric: the NICs are the shared bottleneck, as in the paper's
//! bandwidth-sharing hierarchy (NIC → QPI → PCIe → NVLink).
//!
//! Nodes may carry **several NICs** (`ClusterSpec::nics_per_node`),
//! wired **rail-optimized**: local GPU `l` of every node attaches to
//! rail `l % k`, so the `j`-th member of each node's collective shard
//! always exits through the same rail — the topology that lets 2-level
//! hierarchical all-reduce drive all `k` NICs concurrently. The spine
//! is non-blocking by default; an `oversubscription` ratio `> 1`
//! inserts one shared trunk link per rail with
//! `n_nodes · nic_bandwidth / ratio` capacity, modeling a tapered
//! fat-tree core.

pub mod presets;

pub use presets::Preset;

use crate::util::time::{Ps, SEC};

/// Global device (GPU) index, dense in `0..cluster.num_devices()`.
pub type DeviceId = usize;

/// Dense physical-link index.
pub type LinkId = usize;

/// GPU model parameters used by the roofline cost model and the
/// emulator's interference model.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Marketing name, e.g. `"V100"`.
    pub name: String,
    /// Peak dense FP32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM/GDDR bandwidth, bytes/s.
    pub mem_bandwidth: f64,
    /// Device memory capacity, bytes.
    pub memory_bytes: u64,
    /// Overlap interference factor δ: when computation and communication
    /// overlap on this device, both slow down by ≈ (1 + δ). This is the
    /// physical effect the paper's profiled γ captures.
    pub overlap_interference: f64,
}

/// Physical link classes, ordered top-to-bottom in the sharing
/// hierarchy of Fig. 7 (NIC checked first, then QPI, PCIe, NVLink).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LinkKind {
    /// Node NIC (Ethernet/InfiniBand port).
    Nic,
    /// CPU socket interconnect.
    Qpi,
    /// PCIe leaf or switch uplink.
    Pcie,
    /// NVLink port into the NVSwitch fabric.
    NvLink,
}

/// One shared physical link.
#[derive(Debug, Clone)]
pub struct Link {
    /// Dense id.
    pub id: LinkId,
    /// Hierarchy class.
    pub kind: LinkKind,
    /// Capacity in bytes/s.
    pub bandwidth: f64,
    /// Base latency (the α of the α-β model) in picoseconds.
    pub latency: Ps,
}

impl Link {
    /// Time to move `bytes` over this link at full capacity.
    pub fn transfer_ps(&self, bytes: u64) -> Ps {
        self.latency + (bytes as f64 / self.bandwidth * SEC as f64) as Ps
    }
}

/// Intra-node fabric shape.
#[derive(Debug, Clone)]
enum IntraFabric {
    /// Non-blocking NVSwitch; `port[d]` is each GPU's NVLink port.
    NvSwitch,
    /// PCIe tree with `gpus_per_switch` GPUs per switch and one switch
    /// per socket; cross-socket traffic crosses QPI.
    PcieTree { gpus_per_switch: usize },
}

/// A described training cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Configuration name (e.g. `"HC2"`).
    pub name: String,
    /// Number of nodes.
    pub n_nodes: usize,
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// Device model (homogeneous clusters, as in the paper).
    pub device: DeviceSpec,
    /// All physical links.
    pub links: Vec<Link>,
    fabric: IntraFabric,
    /// Per-device leaf link (NVLink port or PCIe leaf).
    port: Vec<LinkId>,
    /// Per-node, per-switch uplink links (PCIe tree only).
    uplink: Vec<Vec<LinkId>>,
    /// Per-node QPI link (PCIe tree only).
    qpi: Vec<Option<LinkId>>,
    /// Per-node rail NIC links (empty for single-node clusters).
    nics: Vec<Vec<LinkId>>,
    /// NICs (rails) per node.
    nics_per_node: usize,
    /// Per-rail spine trunk links (only when oversubscribed).
    trunk: Vec<LinkId>,
}

/// Parameters for building a cluster by hand (presets call this).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Cluster display name.
    pub name: String,
    /// Node count.
    pub n_nodes: usize,
    /// GPUs per node.
    pub gpus_per_node: usize,
    /// GPU model.
    pub device: DeviceSpec,
    /// Intra-node fabric: `Some(gpus_per_switch)` = PCIe tree,
    /// `None` = NVSwitch.
    pub pcie_tree: Option<usize>,
    /// Per-GPU intra-node port bandwidth, bytes/s.
    pub port_bandwidth: f64,
    /// Port latency, ps.
    pub port_latency: Ps,
    /// PCIe switch uplink bandwidth (PCIe tree only), bytes/s.
    pub uplink_bandwidth: f64,
    /// QPI bandwidth (PCIe tree only), bytes/s.
    pub qpi_bandwidth: f64,
    /// NIC bandwidth per node, bytes/s (multi-node only).
    pub nic_bandwidth: f64,
    /// NIC latency, ps.
    pub nic_latency: Ps,
    /// NICs (rails) per node; must divide `gpus_per_node`. GPUs attach
    /// rail-optimized: local GPU `l` exits through rail `l % k`.
    pub nics_per_node: usize,
    /// Fat-tree core oversubscription ratio (`≥ 1.0`); `1.0` keeps the
    /// spine non-blocking, larger values insert per-rail trunk links
    /// with `n_nodes · nic_bandwidth / ratio` capacity.
    pub oversubscription: f64,
}

impl Cluster {
    /// Build a cluster from an explicit spec.
    pub fn from_spec(spec: &ClusterSpec) -> crate::Result<Self> {
        if spec.n_nodes == 0 || spec.gpus_per_node == 0 {
            return Err(crate::Error::InvalidCluster(
                "need at least one node and one GPU per node".into(),
            ));
        }
        // NIC/port consistency. Before this check, a spec asking for
        // more rails than ports (or a non-dividing count) would have
        // silently collapsed every flow onto rail 0.
        if spec.nics_per_node == 0 {
            return Err(crate::Error::Config(
                "nics_per_node must be at least 1".into(),
            ));
        }
        if spec.nics_per_node > spec.gpus_per_node {
            return Err(crate::Error::Config(format!(
                "nics_per_node {} exceeds gpus_per_node {}: each rail needs a GPU port",
                spec.nics_per_node, spec.gpus_per_node
            )));
        }
        if spec.gpus_per_node % spec.nics_per_node != 0 {
            return Err(crate::Error::Config(format!(
                "gpus_per_node {} not divisible by nics_per_node {}: rail mapping would be uneven",
                spec.gpus_per_node, spec.nics_per_node
            )));
        }
        if !(spec.oversubscription >= 1.0) {
            return Err(crate::Error::Config(format!(
                "oversubscription must be >= 1.0, got {}",
                spec.oversubscription
            )));
        }
        let mut links = Vec::new();
        let mut alloc = |kind: LinkKind, bw: f64, lat: Ps| -> LinkId {
            let id = links.len();
            links.push(Link {
                id,
                kind,
                bandwidth: bw,
                latency: lat,
            });
            id
        };
        let n_dev = spec.n_nodes * spec.gpus_per_node;
        let fabric = match spec.pcie_tree {
            Some(gps) => {
                if spec.gpus_per_node % gps != 0 {
                    return Err(crate::Error::InvalidCluster(format!(
                        "gpus_per_node {} not divisible by gpus_per_switch {gps}",
                        spec.gpus_per_node
                    )));
                }
                IntraFabric::PcieTree { gpus_per_switch: gps }
            }
            None => IntraFabric::NvSwitch,
        };
        let port_kind = match fabric {
            IntraFabric::NvSwitch => LinkKind::NvLink,
            IntraFabric::PcieTree { .. } => LinkKind::Pcie,
        };
        let port: Vec<LinkId> = (0..n_dev)
            .map(|_| alloc(port_kind, spec.port_bandwidth, spec.port_latency))
            .collect();
        let mut uplink = vec![Vec::new(); spec.n_nodes];
        let mut qpi = vec![None; spec.n_nodes];
        if let IntraFabric::PcieTree { gpus_per_switch } = fabric {
            let n_switch = spec.gpus_per_node / gpus_per_switch;
            for n in 0..spec.n_nodes {
                uplink[n] = (0..n_switch)
                    .map(|_| alloc(LinkKind::Pcie, spec.uplink_bandwidth, spec.port_latency))
                    .collect();
                if n_switch > 1 {
                    qpi[n] = Some(alloc(LinkKind::Qpi, spec.qpi_bandwidth, spec.port_latency));
                }
            }
        }
        let nics: Vec<Vec<LinkId>> = (0..spec.n_nodes)
            .map(|_| {
                if spec.n_nodes > 1 {
                    (0..spec.nics_per_node)
                        .map(|_| alloc(LinkKind::Nic, spec.nic_bandwidth, spec.nic_latency))
                        .collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        let trunk: Vec<LinkId> = if spec.n_nodes > 1 && spec.oversubscription > 1.0 {
            let bw = spec.n_nodes as f64 * spec.nic_bandwidth / spec.oversubscription;
            (0..spec.nics_per_node)
                .map(|_| alloc(LinkKind::Nic, bw, spec.nic_latency))
                .collect()
        } else {
            Vec::new()
        };
        Ok(Cluster {
            name: spec.name.clone(),
            n_nodes: spec.n_nodes,
            gpus_per_node: spec.gpus_per_node,
            device: spec.device.clone(),
            links,
            fabric,
            port,
            uplink,
            qpi,
            nics,
            nics_per_node: spec.nics_per_node,
            trunk,
        })
    }

    /// Total GPU count.
    pub fn num_devices(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    /// Node index of device `d`.
    pub fn node_of(&self, d: DeviceId) -> usize {
        d / self.gpus_per_node
    }

    /// Switch index (within its node) of device `d` (PCIe tree only;
    /// NVSwitch clusters have a single logical switch 0).
    pub fn switch_of(&self, d: DeviceId) -> usize {
        match self.fabric {
            IntraFabric::NvSwitch => 0,
            IntraFabric::PcieTree { gpus_per_switch } => {
                (d % self.gpus_per_node) / gpus_per_switch
            }
        }
    }

    /// The leaf port link of device `d`.
    pub fn port_of(&self, d: DeviceId) -> LinkId {
        self.port[d]
    }

    /// Rail (NIC index within its node) device `d` exits through.
    pub fn rail_of(&self, d: DeviceId) -> usize {
        (d % self.gpus_per_node) % self.nics_per_node
    }

    /// The rail NIC links of one node (empty for single-node clusters).
    pub fn node_nics(&self, node: usize) -> &[LinkId] {
        &self.nics[node]
    }

    /// The ordered link path from device `a` to device `b`. Empty iff
    /// `a == b`. Paths are symmetric.
    pub fn path(&self, a: DeviceId, b: DeviceId) -> Vec<LinkId> {
        if a == b {
            return Vec::new();
        }
        let (na, nb) = (self.node_of(a), self.node_of(b));
        let mut p = vec![self.port[a]];
        if na == nb {
            if let IntraFabric::PcieTree { .. } = self.fabric {
                let (sa, sb) = (self.switch_of(a), self.switch_of(b));
                if sa != sb {
                    p.push(self.uplink[na][sa]);
                    if let Some(q) = self.qpi[na] {
                        p.push(q);
                    }
                    p.push(self.uplink[na][sb]);
                }
            }
        } else {
            if let IntraFabric::PcieTree { .. } = self.fabric {
                p.push(self.uplink[na][self.switch_of(a)]);
            }
            let (ra, rb) = (self.rail_of(a), self.rail_of(b));
            p.push(self.nics[na][ra]);
            if !self.trunk.is_empty() {
                // Oversubscribed core: the flow crosses the source
                // rail's trunk (and the destination rail's, when
                // different — same rail means one spine hop).
                p.push(self.trunk[ra]);
                if rb != ra {
                    p.push(self.trunk[rb]);
                }
            }
            p.push(self.nics[nb][rb]);
            if let IntraFabric::PcieTree { .. } = self.fabric {
                p.push(self.uplink[nb][self.switch_of(b)]);
            }
        }
        p.push(self.port[b]);
        p
    }

    /// Bottleneck bandwidth of the `a → b` path, bytes/s.
    pub fn pair_bandwidth(&self, a: DeviceId, b: DeviceId) -> f64 {
        self.path(a, b)
            .iter()
            .map(|&l| self.links[l].bandwidth)
            .fold(f64::INFINITY, f64::min)
    }

    /// Total latency (α) of the `a → b` path, ps.
    pub fn pair_latency(&self, a: DeviceId, b: DeviceId) -> Ps {
        self.path(a, b).iter().map(|&l| self.links[l].latency).sum()
    }

    /// NCCL-style ring order for a communication group: devices sorted so
    /// that same-node (and same-switch) devices are adjacent, minimizing
    /// cross-hierarchy hops.
    pub fn ring_order(&self, group: &[DeviceId]) -> Vec<DeviceId> {
        let mut g = group.to_vec();
        g.sort_by_key(|&d| (self.node_of(d), self.switch_of(d), d));
        g
    }

    /// Effective per-rank *bus bandwidth* of a ring over `group`: walk
    /// the NCCL-style ring, count how many ring segments traverse each
    /// physical link, and take the worst `bandwidth / multiplicity`.
    /// This is the paper's "NCCL topo detection" analogue (§VII): a ring
    /// that crosses QPI twice only gets half the QPI bandwidth per
    /// segment — exactly the fine-grained topology effect flat models
    /// (FlexFlow-Sim) miss.
    ///
    /// A 2-rank "ring" degenerates to a single full-duplex exchange:
    /// its two wrap-around segments are the same duplex links in
    /// opposite directions, so the wrap is counted once (counting both
    /// would halve the reported bandwidth for every 2-GPU group).
    pub fn ring_bus_bandwidth(&self, group: &[DeviceId]) -> f64 {
        if group.len() < 2 {
            return f64::INFINITY;
        }
        let ring = self.ring_order(group);
        let segments = if ring.len() == 2 { 1 } else { ring.len() };
        let mut uses: std::collections::HashMap<LinkId, u32> = Default::default();
        for i in 0..segments {
            let a = ring[i];
            let b = ring[(i + 1) % ring.len()];
            for l in self.path(a, b) {
                *uses.entry(l).or_insert(0) += 1;
            }
        }
        let mut bw = f64::INFINITY;
        for (l, n) in uses {
            bw = bw.min(self.links[l].bandwidth / n as f64);
        }
        bw
    }

    /// Worst pairwise α along the ring, ps.
    pub fn ring_latency(&self, group: &[DeviceId]) -> Ps {
        if group.len() < 2 {
            return 0;
        }
        let ring = self.ring_order(group);
        let mut lat = 0;
        for i in 0..ring.len() {
            let a = ring[i];
            let b = ring[(i + 1) % ring.len()];
            lat = lat.max(self.pair_latency(a, b));
        }
        lat
    }

    /// All links of a given kind (used by bandwidth-sharing detection to
    /// walk the hierarchy top-down).
    pub fn links_of_kind(&self, kind: LinkKind) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.kind == kind)
    }

    /// Build one of the paper's hardware configurations, overriding the
    /// node count (the paper sweeps GPU counts within each config).
    pub fn preset(p: Preset, n_nodes: usize) -> Cluster {
        presets::build(p, n_nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hc1() -> Cluster {
        Cluster::preset(Preset::HC1, 1)
    }
    fn hc2() -> Cluster {
        Cluster::preset(Preset::HC2, 4)
    }

    #[test]
    fn device_and_node_indexing() {
        let c = hc2();
        assert_eq!(c.num_devices(), 32);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(8), 1);
        assert_eq!(c.node_of(31), 3);
    }

    #[test]
    fn path_is_empty_for_self() {
        let c = hc2();
        assert!(c.path(3, 3).is_empty());
    }

    #[test]
    fn same_node_nvlink_path_has_two_ports() {
        let c = hc2();
        let p = c.path(0, 5);
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|&l| c.links[l].kind == LinkKind::NvLink));
    }

    #[test]
    fn cross_node_path_crosses_both_nics() {
        let c = hc2();
        let p = c.path(0, 9);
        let nics = p.iter().filter(|&&l| c.links[l].kind == LinkKind::Nic).count();
        assert_eq!(nics, 2);
        // NIC is the bottleneck.
        assert!(c.pair_bandwidth(0, 9) < c.pair_bandwidth(0, 1));
    }

    #[test]
    fn hc1_cross_socket_crosses_qpi() {
        let c = hc1();
        // GPUs 0-3 on switch 0, 4-7 on switch 1.
        assert_eq!(c.switch_of(3), 0);
        assert_eq!(c.switch_of(4), 1);
        let p = c.path(0, 4);
        assert!(p.iter().any(|&l| c.links[l].kind == LinkKind::Qpi));
        let p2 = c.path(0, 3);
        assert!(p2.iter().all(|&l| c.links[l].kind == LinkKind::Pcie));
    }

    #[test]
    fn paths_are_symmetric_in_bandwidth() {
        let c = hc2();
        for (a, b) in [(0, 1), (0, 9), (7, 25)] {
            assert_eq!(c.pair_bandwidth(a, b), c.pair_bandwidth(b, a));
            assert_eq!(c.pair_latency(a, b), c.pair_latency(b, a));
        }
    }

    #[test]
    fn ring_order_groups_by_node() {
        let c = hc2();
        let ring = c.ring_order(&[9, 0, 8, 1]);
        assert_eq!(ring, vec![0, 1, 8, 9]);
    }

    #[test]
    fn intra_node_ring_faster_than_cross_node() {
        let c = hc2();
        let intra: Vec<usize> = (0..8).collect();
        let cross: Vec<usize> = vec![0, 8, 16, 24];
        assert!(c.ring_bus_bandwidth(&intra) > c.ring_bus_bandwidth(&cross));
    }

    /// Regression: the 2-rank ring used to walk both wrap-around
    /// segments of the degenerate "ring", double-counting every duplex
    /// link and halving the reported bus bandwidth for 2-GPU groups.
    #[test]
    fn two_rank_ring_gets_full_duplex_bandwidth() {
        let c = hc2();
        // Same-node V100 pair: path is two 150 GB/s NVLink ports, each
        // traversed once by the single duplex exchange.
        assert_eq!(c.ring_bus_bandwidth(&[0, 1]), 150e9);
        // Cross-node pair: the 12 GB/s NIC is the bottleneck, again
        // counted once.
        assert_eq!(c.ring_bus_bandwidth(&[0, 8]), 12e9);
        // 3-rank rings still pay the real multiplicity (each port
        // carries that device's in- and out-segment).
        assert_eq!(c.ring_bus_bandwidth(&[0, 1, 2]), 150e9 / 2.0);
    }

    #[test]
    fn single_device_group_is_free() {
        let c = hc2();
        assert_eq!(c.ring_bus_bandwidth(&[3]), f64::INFINITY);
        assert_eq!(c.ring_latency(&[3]), 0);
    }

    #[test]
    fn from_spec_rejects_empty() {
        let mut spec = presets::spec(Preset::HC1, 1);
        spec.n_nodes = 0;
        assert!(Cluster::from_spec(&spec).is_err());
    }

    #[test]
    fn multi_nic_rails_route_by_local_index() {
        let c = Cluster::preset(Preset::HC4, 4);
        // Both endpoints on rail 0: four links, one NIC per side.
        let p = c.path(0, 8);
        assert_eq!(p.len(), 4);
        assert_eq!(p[1], c.node_nics(0)[0]);
        assert_eq!(p[2], c.node_nics(1)[0]);
        // Local index 1 exits through rail 1.
        assert_eq!(c.rail_of(9), 1);
        assert_eq!(c.path(1, 9)[1], c.node_nics(0)[1]);
        // Same-node traffic never touches a NIC.
        assert!(c
            .path(0, 1)
            .iter()
            .all(|&l| c.links[l].kind == LinkKind::NvLink));
    }

    #[test]
    fn distinct_rails_use_disjoint_links() {
        let c = Cluster::preset(Preset::HC4, 2);
        let a: std::collections::HashSet<LinkId> = c.path(0, 8).into_iter().collect();
        let b: std::collections::HashSet<LinkId> = c.path(1, 9).into_iter().collect();
        assert!(a.is_disjoint(&b), "rail 0 and rail 1 flows share a link");
    }

    #[test]
    fn two_rank_duplex_on_multi_nic_counts_wrap_once() {
        // The 2-rank degenerate ring that bit PR 3, now on rails.
        let c = Cluster::preset(Preset::HC4, 2);
        assert_eq!(c.ring_bus_bandwidth(&[0, 8]), 12e9);
        assert_eq!(c.ring_bus_bandwidth(&[0, 1]), 150e9);
    }

    #[test]
    fn oversubscribed_trunk_caps_cross_node_bandwidth() {
        let mut s = presets::spec(Preset::HC4, 4);
        s.oversubscription = 8.0;
        let c = Cluster::from_spec(&s).unwrap();
        // Trunk capacity: 4 nodes × 12 GB/s ÷ 8 = 6 GB/s, the new
        // bottleneck below the 12 GB/s NICs.
        assert_eq!(c.pair_bandwidth(0, 8), 6e9);
        // Same rail: one trunk hop; different rails: two.
        assert_eq!(c.path(0, 8).len(), 5);
        assert_eq!(c.path(0, 9).len(), 6);
        // Intra-node traffic is unaffected.
        assert_eq!(c.pair_bandwidth(0, 1), 150e9);
    }

    #[test]
    fn single_node_multi_nic_degenerates_to_intra_fabric() {
        let c = Cluster::from_spec(&presets::spec(Preset::HC4, 1)).unwrap();
        assert!(c.node_nics(0).is_empty());
        assert_eq!(c.path(0, 5).len(), 2);
        assert_eq!(c.pair_bandwidth(0, 5), 150e9);
    }

    #[test]
    fn spec_rejects_inconsistent_nic_counts() {
        // Pre-fix, these specs built "successfully" with every flow
        // silently collapsed onto the node's first NIC.
        let cases: Vec<(usize, f64)> = vec![(0, 1.0), (3, 1.0), (16, 1.0), (1, 0.5)];
        for (k, os) in cases {
            let mut s = presets::spec(Preset::HC2, 2);
            s.nics_per_node = k;
            s.oversubscription = os;
            match Cluster::from_spec(&s) {
                Err(crate::Error::Config(_)) => {}
                other => panic!("k={k} os={os}: expected Config error, got {other:?}"),
            }
        }
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let c = hc2();
        let l = &c.links[c.port_of(0)];
        let t1 = l.transfer_ps(1 << 20);
        let t2 = l.transfer_ps(1 << 21);
        assert!(t2 > t1);
        assert!(t2 - l.latency >= 2 * (t1 - l.latency) - 1);
    }
}
