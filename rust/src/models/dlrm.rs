//! DLRM: deep learning recommendation model (Naumov et al.).
//!
//! Dense features pass through a bottom MLP; 26 categorical features go
//! through large embedding-bag lookups; pairwise feature interaction
//! feeds a top MLP producing the CTR logit. The paper's configuration is
//! ≈516M parameters — ≈99.9% of them embedding tables, which is what
//! makes DLRM the bandwidth-sharing stress test where FlexFlow-Sim's
//! flat-topology model breaks down (Table IV: 48% avg error).

use crate::graph::{DType, Graph, GraphBuilder};

/// DLRM hyper-parameters.
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    /// Number of categorical (sparse) features / embedding tables.
    pub n_tables: usize,
    /// Rows per embedding table.
    pub rows_per_table: usize,
    /// Embedding dimension (shared with the bottom-MLP output).
    pub d_embed: usize,
    /// Multi-hot lookups per table per sample.
    pub n_hot: usize,
    /// Dense input features.
    pub n_dense: usize,
    /// Bottom MLP widths (ending at `d_embed`).
    pub bottom_mlp: Vec<usize>,
    /// Top MLP widths (ending at 1).
    pub top_mlp: Vec<usize>,
}

impl DlrmConfig {
    /// ≈516M parameter configuration (26 tables × 620k rows × 32).
    pub fn paper_516m() -> Self {
        DlrmConfig {
            n_tables: 26,
            rows_per_table: 620_000,
            d_embed: 32,
            n_hot: 4,
            n_dense: 13,
            bottom_mlp: vec![512, 256, 32],
            top_mlp: vec![512, 256, 1],
        }
    }

    /// Tiny configuration for tests.
    pub fn tiny() -> Self {
        DlrmConfig {
            n_tables: 4,
            rows_per_table: 1000,
            d_embed: 16,
            n_hot: 2,
            n_dense: 13,
            bottom_mlp: vec![64, 16],
            top_mlp: vec![32, 1],
        }
    }
}

/// Build DLRM at `batch` samples per step.
pub fn dlrm(cfg: DlrmConfig, batch: usize) -> Graph {
    assert_eq!(
        *cfg.bottom_mlp.last().unwrap(),
        cfg.d_embed,
        "bottom MLP must end at d_embed"
    );
    let mut b = GraphBuilder::new("dlrm", batch);
    let dense = b.input("dense", &[batch, cfg.n_dense], DType::F32);
    let idx = b.input("indices", &[batch, cfg.n_hot], DType::I64);

    // Bottom MLP over dense features.
    let mut x = dense;
    let mut width = cfg.n_dense;
    b.push_scope("bottom_mlp");
    for (i, &w) in cfg.bottom_mlp.iter().enumerate() {
        x = b.linear(&format!("fc{i}"), x, width, w);
        x = b.relu(&format!("relu{i}"), x);
        width = w;
    }
    b.pop_scope();

    // Embedding bags.
    let mut features = vec![x];
    b.push_scope("embeddings");
    for t in 0..cfg.n_tables {
        let e = b.embedding_bag(
            &format!("table{t}"),
            idx,
            cfg.rows_per_table,
            cfg.d_embed,
            cfg.n_hot,
            DType::F32,
        );
        features.push(e);
    }
    b.pop_scope();

    // Pairwise interaction + top MLP.
    b.push_scope("interact");
    let stacked = b.concat_features("stack", &features, cfg.d_embed);
    let inter = b.interaction("pairwise", stacked);
    let f = cfg.n_tables + 1;
    let inter_w = f * (f + 1) / 2;
    b.pop_scope();

    b.push_scope("top_mlp");
    let mut x = inter;
    let mut width = inter_w;
    for (i, &w) in cfg.top_mlp.iter().enumerate() {
        x = b.linear(&format!("fc{i}"), x, width, w);
        if i + 1 < cfg.top_mlp.len() {
            x = b.relu(&format!("relu{i}"), x);
        }
        width = w;
    }
    b.pop_scope();
    let _ = b.loss("loss", x);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, TensorKind};

    #[test]
    fn tiny_builds() {
        let g = dlrm(DlrmConfig::tiny(), 8);
        assert!(g.validate().is_empty());
        let tables = g
            .layers
            .iter()
            .filter(|l| l.kind == OpKind::Embedding)
            .count();
        assert_eq!(tables, 4);
    }

    #[test]
    fn embeddings_dominate_parameters() {
        let g = dlrm(DlrmConfig::paper_516m(), 8);
        let emb: u64 = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Param && t.name.contains("table"))
            .map(|t| t.numel())
            .sum();
        assert!(emb as f64 / g.num_params() as f64 > 0.99);
    }

    #[test]
    fn interaction_width_matches_feature_count() {
        let cfg = DlrmConfig::tiny();
        let g = dlrm(cfg.clone(), 8);
        let inter = g
            .layers
            .iter()
            .find(|l| l.kind == OpKind::Interaction)
            .unwrap();
        let out = &g.tensors[inter.outputs[0].tensor];
        let f = cfg.n_tables + 1;
        assert_eq!(out.shape, vec![8, f * (f + 1) / 2]);
    }

    #[test]
    fn embedding_reads_are_sparse() {
        let g = dlrm(DlrmConfig::paper_516m(), 8);
        for l in g.layers.iter().filter(|l| l.kind == OpKind::Embedding) {
            assert!(l.param_read_factor < 0.01, "{}", l.name);
        }
    }
}
