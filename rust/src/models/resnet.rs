//! ResNet-50: bottleneck residual network for 224×224 images.
//!
//! Standard v1.5 layout: 7×7/2 stem, max-pool, four stages of bottleneck
//! blocks `[3, 4, 6, 3]` (1×1 reduce → 3×3 → 1×1 expand, projection
//! shortcut on the first block of each stage, stride-2 in the 3×3 of
//! stages 2-4), global average pool, 1000-way classifier.

use crate::graph::{DType, Graph, GraphBuilder, TensorId};

struct BlockIo {
    out: TensorId,
    hw: (usize, usize),
}

#[allow(clippy::too_many_arguments)]
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    c_in: usize,
    width: usize,
    c_out: usize,
    hw: (usize, usize),
    stride: usize,
    project: bool,
) -> BlockIo {
    b.push_scope(name);
    let (y, _) = b.conv2d("conv1", x, c_in, width, hw, 1, 1, 0);
    let y = b.batch_norm("bn1", y);
    let y = b.relu("relu1", y);
    let (y, hw2) = b.conv2d("conv2", y, width, width, hw, 3, stride, 1);
    let y = b.batch_norm("bn2", y);
    let y = b.relu("relu2", y);
    let (y, _) = b.conv2d("conv3", y, width, c_out, hw2, 1, 1, 0);
    let y = b.batch_norm("bn3", y);
    let shortcut = if project {
        let (s, _) = b.conv2d("downsample", x, c_in, c_out, hw, 1, stride, 0);
        b.batch_norm("bn_ds", s)
    } else {
        x
    };
    let y = b.add("res", y, shortcut);
    let out = b.relu("relu_out", y);
    b.pop_scope();
    BlockIo { out, hw: hw2 }
}

fn res_stage(
    b: &mut GraphBuilder,
    name: &str,
    mut x: TensorId,
    c_in: usize,
    width: usize,
    blocks: usize,
    mut hw: (usize, usize),
    stride: usize,
) -> BlockIo {
    let c_out = width * 4;
    b.push_scope(name);
    for i in 0..blocks {
        let io = bottleneck(
            b,
            &format!("block{i}"),
            x,
            if i == 0 { c_in } else { c_out },
            width,
            c_out,
            hw,
            if i == 0 { stride } else { 1 },
            i == 0,
        );
        x = io.out;
        hw = io.hw;
    }
    b.pop_scope();
    BlockIo { out: x, hw }
}

/// Build ResNet-50 for 224×224×3 inputs and 1000 classes.
pub fn resnet50(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("resnet50", batch);
    let x = b.input("images", &[batch, 3, 224 * 224], DType::F32);
    let (x, hw) = b.scoped("stem", |b| {
        let (y, _hw) = b.conv2d("conv1", x, 3, 64, (224, 224), 7, 2, 3);
        let y = b.batch_norm("bn1", y);
        let y = b.relu("relu1", y);
        // 3×3/2 max pool: 112→56.
        let y = b.pool("maxpool", y, 56 * 56);
        (y, (56usize, 56usize))
    });
    let s1 = res_stage(&mut b, "layer1", x, 64, 64, 3, hw, 1);
    let s2 = res_stage(&mut b, "layer2", s1.out, 256, 128, 4, s1.hw, 2);
    let s3 = res_stage(&mut b, "layer3", s2.out, 512, 256, 6, s2.hw, 2);
    let s4 = res_stage(&mut b, "layer4", s3.out, 1024, 512, 3, s3.hw, 2);
    assert_eq!(s4.hw, (7, 7));
    b.scoped("head", |b| {
        let pooled = b.pool("avgpool", s4.out, 1);
        let flat = b.flatten("flatten", pooled);
        let logits = b.linear("fc", flat, 2048, 1000);
        let _ = b.loss("loss", logits);
    });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn conv_count_is_53() {
        // 1 stem + 3×(3+1) + 4×3+1 + 6×3+1 + 3×3+1 = 53 convs
        let g = resnet50(8);
        let convs = g.layers.iter().filter(|l| l.kind == OpKind::Conv2d).count();
        assert_eq!(convs, 53);
    }

    #[test]
    fn spatial_sizes_halve_per_stage() {
        let g = resnet50(8);
        // layer4 output is [b, 2048, 49]
        let l4 = g
            .layers
            .iter()
            .filter(|l| l.path_string().starts_with("layer4"))
            .last()
            .unwrap();
        let out = &g.tensors[l4.outputs[0].tensor];
        assert_eq!(out.shape, vec![8, 2048, 49]);
    }

    #[test]
    fn total_fwd_flops_near_reference() {
        // ResNet-50 ≈ 4.1 GFLOPs MACs → ~8.2 GFLOP (mul+add) per image.
        let g = resnet50(1);
        let gf = g.total_fwd_flops() as f64 / 1e9;
        assert!((gf - 8.2).abs() / 8.2 < 0.2, "got {gf} GFLOP");
    }
}
