//! Inception-V3 for 299×299 images (torchvision channel configuration).
//!
//! The branchy inception blocks exercise the graph IR's multi-consumer /
//! multi-producer paths: each block fans an activation out to 3-4
//! parallel branches whose outputs merge through channel concatenation.

use crate::graph::{DType, Graph, GraphBuilder, TensorId};

/// conv → bn → relu, square kernel.
#[allow(clippy::too_many_arguments)]
fn cbr(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    c_in: usize,
    c_out: usize,
    hw: (usize, usize),
    k: usize,
    stride: usize,
    pad: usize,
) -> (TensorId, (usize, usize)) {
    let (y, nhw) = b.conv2d(&format!("{name}_conv"), x, c_in, c_out, hw, k, stride, pad);
    let y = b.batch_norm(&format!("{name}_bn"), y);
    (b.relu(&format!("{name}_relu"), y), nhw)
}

/// conv → bn → relu, rectangular kernel (same-size output).
#[allow(clippy::too_many_arguments)]
fn cbr_rect(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    c_in: usize,
    c_out: usize,
    hw: (usize, usize),
    k: (usize, usize),
    pad: (usize, usize),
) -> TensorId {
    let (y, _) = b.conv2d_rect(&format!("{name}_conv"), x, c_in, c_out, hw, k, 1, pad);
    let y = b.batch_norm(&format!("{name}_bn"), y);
    b.relu(&format!("{name}_relu"), y)
}

/// InceptionA: 1×1 / 5×5 / double-3×3 / pool branches, same spatial.
fn inception_a(b: &mut GraphBuilder, name: &str, x: TensorId, c_in: usize, pool_f: usize, hw: (usize, usize)) -> TensorId {
    b.scoped(name, |b| {
        let (b1, _) = cbr(b, "b1x1", x, c_in, 64, hw, 1, 1, 0);
        let (b5, _) = cbr(b, "b5x5_1", x, c_in, 48, hw, 1, 1, 0);
        let (b5, _) = cbr(b, "b5x5_2", b5, 48, 64, hw, 5, 1, 2);
        let (d3, _) = cbr(b, "b3x3dbl_1", x, c_in, 64, hw, 1, 1, 0);
        let (d3, _) = cbr(b, "b3x3dbl_2", d3, 64, 96, hw, 3, 1, 1);
        let (d3, _) = cbr(b, "b3x3dbl_3", d3, 96, 96, hw, 3, 1, 1);
        let p = b.pool("pool", x, hw.0 * hw.1);
        let (bp, _) = cbr(b, "bpool", p, c_in, pool_f, hw, 1, 1, 0);
        b.concat_channels("cat", &[b1, b5, d3, bp])
    })
}

/// InceptionB: grid reduction 35→17.
fn inception_b(b: &mut GraphBuilder, name: &str, x: TensorId, c_in: usize, hw: (usize, usize)) -> (TensorId, (usize, usize)) {
    b.scoped(name, |b| {
        let (b3, nhw) = cbr(b, "b3x3", x, c_in, 384, hw, 3, 2, 0);
        let (d3, _) = cbr(b, "b3x3dbl_1", x, c_in, 64, hw, 1, 1, 0);
        let (d3, _) = cbr(b, "b3x3dbl_2", d3, 64, 96, hw, 3, 1, 1);
        let (d3, _) = cbr(b, "b3x3dbl_3", d3, 96, 96, hw, 3, 2, 0);
        let p = b.pool("pool", x, nhw.0 * nhw.1);
        (b.concat_channels("cat", &[b3, d3, p]), nhw)
    })
}

/// InceptionC: factorized 7×7 branches at 17×17.
fn inception_c(b: &mut GraphBuilder, name: &str, x: TensorId, c_in: usize, c7: usize, hw: (usize, usize)) -> TensorId {
    b.scoped(name, |b| {
        let (b1, _) = cbr(b, "b1x1", x, c_in, 192, hw, 1, 1, 0);
        let (s7, _) = cbr(b, "b7x7_1", x, c_in, c7, hw, 1, 1, 0);
        let s7 = cbr_rect(b, "b7x7_2", s7, c7, c7, hw, (1, 7), (0, 3));
        let s7 = cbr_rect(b, "b7x7_3", s7, c7, 192, hw, (7, 1), (3, 0));
        let (d7, _) = cbr(b, "b7x7dbl_1", x, c_in, c7, hw, 1, 1, 0);
        let d7 = cbr_rect(b, "b7x7dbl_2", d7, c7, c7, hw, (7, 1), (3, 0));
        let d7 = cbr_rect(b, "b7x7dbl_3", d7, c7, c7, hw, (1, 7), (0, 3));
        let d7 = cbr_rect(b, "b7x7dbl_4", d7, c7, c7, hw, (7, 1), (3, 0));
        let d7 = cbr_rect(b, "b7x7dbl_5", d7, c7, 192, hw, (1, 7), (0, 3));
        let p = b.pool("pool", x, hw.0 * hw.1);
        let (bp, _) = cbr(b, "bpool", p, c_in, 192, hw, 1, 1, 0);
        b.concat_channels("cat", &[b1, s7, d7, bp])
    })
}

/// InceptionD: grid reduction 17→8.
fn inception_d(b: &mut GraphBuilder, name: &str, x: TensorId, c_in: usize, hw: (usize, usize)) -> (TensorId, (usize, usize)) {
    b.scoped(name, |b| {
        let (b3, _) = cbr(b, "b3x3_1", x, c_in, 192, hw, 1, 1, 0);
        let (b3, nhw) = cbr(b, "b3x3_2", b3, 192, 320, hw, 3, 2, 0);
        let (b7, _) = cbr(b, "b7x7_1", x, c_in, 192, hw, 1, 1, 0);
        let b7 = cbr_rect(b, "b7x7_2", b7, 192, 192, hw, (1, 7), (0, 3));
        let b7 = cbr_rect(b, "b7x7_3", b7, 192, 192, hw, (7, 1), (3, 0));
        let (b7, _) = cbr(b, "b7x7_4", b7, 192, 192, hw, 3, 2, 0);
        let p = b.pool("pool", x, nhw.0 * nhw.1);
        (b.concat_channels("cat", &[b3, b7, p]), nhw)
    })
}

/// InceptionE: expanded 3×3 branches at 8×8.
fn inception_e(b: &mut GraphBuilder, name: &str, x: TensorId, c_in: usize, hw: (usize, usize)) -> TensorId {
    b.scoped(name, |b| {
        let (b1, _) = cbr(b, "b1x1", x, c_in, 320, hw, 1, 1, 0);
        let (b3, _) = cbr(b, "b3x3_1", x, c_in, 384, hw, 1, 1, 0);
        let b3a = cbr_rect(b, "b3x3_2a", b3, 384, 384, hw, (1, 3), (0, 1));
        let b3b = cbr_rect(b, "b3x3_2b", b3, 384, 384, hw, (3, 1), (1, 0));
        let b3 = b.concat_channels("b3cat", &[b3a, b3b]);
        let (d3, _) = cbr(b, "b3x3dbl_1", x, c_in, 448, hw, 1, 1, 0);
        let (d3, _) = cbr(b, "b3x3dbl_2", d3, 448, 384, hw, 3, 1, 1);
        let d3a = cbr_rect(b, "b3x3dbl_3a", d3, 384, 384, hw, (1, 3), (0, 1));
        let d3b = cbr_rect(b, "b3x3dbl_3b", d3, 384, 384, hw, (3, 1), (1, 0));
        let d3 = b.concat_channels("d3cat", &[d3a, d3b]);
        let p = b.pool("pool", x, hw.0 * hw.1);
        let (bp, _) = cbr(b, "bpool", p, c_in, 192, hw, 1, 1, 0);
        b.concat_channels("cat", &[b1, b3, d3, bp])
    })
}

/// Build Inception-V3 for 299×299×3 inputs and 1000 classes.
pub fn inception_v3(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("inception_v3", batch);
    let x = b.input("images", &[batch, 3, 299 * 299], DType::F32);
    // Stem: 299→35.
    let (x, hw) = b.scoped("stem", |b| {
        let (x, hw) = cbr(b, "conv1", x, 3, 32, (299, 299), 3, 2, 0); // 149
        let (x, hw) = cbr(b, "conv2", x, 32, 32, hw, 3, 1, 0); // 147
        let (x, hw) = cbr(b, "conv3", x, 32, 64, hw, 3, 1, 1); // 147
        let hw2 = ((hw.0 - 1) / 2, (hw.1 - 1) / 2); // maxpool 3/2 → 73
        let x = b.pool("pool1", x, hw2.0 * hw2.1);
        let (x, hw3) = cbr(b, "conv4", x, 64, 80, hw2, 1, 1, 0); // 73
        let (x, hw4) = cbr(b, "conv5", x, 80, 192, hw3, 3, 1, 0); // 71
        let hw5 = ((hw4.0 - 1) / 2, (hw4.1 - 1) / 2); // maxpool → 35
        let x = b.pool("pool2", x, hw5.0 * hw5.1);
        (x, hw5)
    });
    assert_eq!(hw, (35, 35));
    let x = inception_a(&mut b, "mixed5b", x, 192, 32, hw);
    let x = inception_a(&mut b, "mixed5c", x, 256, 64, hw);
    let x = inception_a(&mut b, "mixed5d", x, 288, 64, hw);
    let (x, hw) = inception_b(&mut b, "mixed6a", x, 288, hw);
    assert_eq!(hw, (17, 17));
    let x = inception_c(&mut b, "mixed6b", x, 768, 128, hw);
    let x = inception_c(&mut b, "mixed6c", x, 768, 160, hw);
    let x = inception_c(&mut b, "mixed6d", x, 768, 160, hw);
    let x = inception_c(&mut b, "mixed6e", x, 768, 192, hw);
    let (x, hw) = inception_d(&mut b, "mixed7a", x, 768, hw);
    assert_eq!(hw, (8, 8));
    let x = inception_e(&mut b, "mixed7b", x, 1280, hw);
    let x = inception_e(&mut b, "mixed7c", x, 2048, hw);
    b.scoped("head", |b| {
        let pooled = b.pool("avgpool", x, 1);
        let flat = b.flatten("flatten", pooled);
        let logits = b.linear("fc", flat, 2048, 1000);
        let _ = b.loss("loss", logits);
    });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn builds_and_validates() {
        let g = inception_v3(8);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn conv_count_matches_torchvision() {
        // torchvision Inception-V3 has 94 conv layers.
        let g = inception_v3(8);
        let convs = g.layers.iter().filter(|l| l.kind == OpKind::Conv2d).count();
        assert_eq!(convs, 94);
    }

    #[test]
    fn branches_share_the_block_input() {
        let g = inception_v3(8);
        let cons = g.consumers();
        // The stem output feeds all 4 branches of mixed5b.
        let stem_out = g
            .layers
            .iter()
            .find(|l| l.path_string() == "stem.pool2")
            .unwrap()
            .outputs[0]
            .tensor;
        assert!(cons[stem_out].len() >= 4, "{:?}", cons[stem_out]);
    }

    #[test]
    fn total_fwd_flops_near_reference() {
        // Inception-V3 ≈ 5.7 GMACs → ≈ 11.4 GFLOP per image.
        let g = inception_v3(1);
        let gf = g.total_fwd_flops() as f64 / 1e9;
        assert!((gf - 11.4).abs() / 11.4 < 0.25, "got {gf} GFLOP");
    }
}
