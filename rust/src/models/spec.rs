//! Open workload selector: [`ModelSpec`].
//!
//! The session, runtime, and CLI layers used to match on [`ModelKind`]
//! directly, so adding a workload meant editing every call site. They
//! now carry a `ModelSpec` — an open union of
//!
//! - **presets**: a [`ModelKind`] plus optional size-override knobs
//!   (`--layers/--hidden/--experts`, GPT / MoE families only), and
//! - **files**: an external JSON layer graph loaded through
//!   [`super::import`] (`--model-file PATH`).
//!
//! A bare preset behaves exactly like the old enum: `name()` returns the
//! same display string and `graph_key()` the same hash, so session
//! memoization keys, sweep dedup, and every `--json` document are
//! byte-identical to the pre-`ModelSpec` code.

use super::import;
use crate::graph::Graph;
use crate::models::{gpt2, moe_gpt, GptConfig, ModelKind, MoeGptConfig};
use crate::{Error, Result};

/// A workload: which graph to build at a given global batch size.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ModelSpec {
    /// A built-in preset, optionally resized.
    Preset {
        /// The base model.
        kind: ModelKind,
        /// Override transformer block count (GPT / MoE only).
        layers: Option<usize>,
        /// Override model width (GPT / MoE only).
        hidden: Option<usize>,
        /// Override experts per MoE layer (MoE only).
        experts: Option<usize>,
    },
    /// An external JSON layer graph (see [`super::import`] for the
    /// format).
    File {
        /// Source path, for reports only — identity is the content hash.
        path: String,
        /// Graph name declared in the file.
        name: String,
        /// Raw file contents.
        text: String,
    },
}

/// FNV-1a, matching [`ModelKind::graph_key`]'s string hash.
fn fnv(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl ModelSpec {
    /// A preset without overrides (the common case; drop-in for the old
    /// bare `ModelKind`).
    pub fn preset(kind: ModelKind) -> ModelSpec {
        ModelSpec::Preset {
            kind,
            layers: None,
            hidden: None,
            experts: None,
        }
    }

    /// Parse a preset name (`"gpt2"`, `"moe-llama-7b"`, ...). File
    /// models come through [`ModelSpec::from_file`] instead.
    pub fn parse(s: &str) -> Option<ModelSpec> {
        ModelKind::parse(s).map(ModelSpec::preset)
    }

    /// Load an external model file, validating the format eagerly (a
    /// probe build at batch 1) so bad files fail at the CLI boundary,
    /// not deep inside a sweep.
    pub fn from_file(path: &str) -> Result<ModelSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("model file {path}: {e}")))?;
        let probe = import::import_json(&text, 1)?;
        Ok(ModelSpec::File {
            path: path.to_string(),
            name: probe.name,
            text,
        })
    }

    /// The underlying preset, if this is one.
    pub fn kind(&self) -> Option<ModelKind> {
        match self {
            ModelSpec::Preset { kind, .. } => Some(*kind),
            ModelSpec::File { .. } => None,
        }
    }

    /// Display name. Equal to [`ModelKind::name`] for bare presets;
    /// overridden knobs are appended (`GPT-2~l24~h1024`) so reports and
    /// cache keys distinguish resized variants.
    pub fn name(&self) -> String {
        match self {
            ModelSpec::Preset {
                kind,
                layers,
                hidden,
                experts,
            } => {
                let mut n = kind.name().to_string();
                if let Some(l) = layers {
                    n.push_str(&format!("~l{l}"));
                }
                if let Some(h) = hidden {
                    n.push_str(&format!("~h{h}"));
                }
                if let Some(e) = experts {
                    n.push_str(&format!("~e{e}"));
                }
                n
            }
            ModelSpec::File { name, .. } => name.clone(),
        }
    }

    /// Stable identity of the `(model, batch)` graph, for keying
    /// cross-request caches. Bare presets hash exactly like
    /// [`ModelKind::graph_key`] (the knob suffix is empty); file models
    /// hash the file *contents*, so an identical re-save still hits the
    /// session cache and any edit misses it.
    pub fn graph_key(&self, batch: usize) -> u64 {
        let h = match self {
            ModelSpec::Preset { .. } => fnv(self.name().bytes()),
            ModelSpec::File { text, .. } => fnv(text.bytes()),
        };
        h ^ (batch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Build the graph at a global batch size.
    pub fn build(&self, batch: usize) -> Result<Graph> {
        match self {
            ModelSpec::Preset {
                kind,
                layers: None,
                hidden: None,
                experts: None,
            } => Ok(kind.build(batch)),
            ModelSpec::Preset {
                kind,
                layers,
                hidden,
                experts,
            } => {
                let check = |cfg_model: usize, n_head: usize| -> Result<()> {
                    if cfg_model % n_head != 0 {
                        return Err(Error::Config(format!(
                            "--hidden {cfg_model}: not divisible by {n_head} attention heads"
                        )));
                    }
                    Ok(())
                };
                match kind {
                    ModelKind::Gpt2 | ModelKind::Gpt15B => {
                        if experts.is_some() {
                            return Err(Error::Config(format!(
                                "--experts: {} is not an MoE model",
                                kind.name()
                            )));
                        }
                        let mut cfg = if *kind == ModelKind::Gpt2 {
                            GptConfig::gpt2_117m()
                        } else {
                            GptConfig::gpt2_1_5b()
                        };
                        if let Some(l) = layers {
                            cfg.n_layer = *l;
                        }
                        if let Some(h) = hidden {
                            cfg.d_model = *h;
                        }
                        check(cfg.d_model, cfg.n_head)?;
                        Ok(gpt2(cfg, batch))
                    }
                    ModelKind::MoeGpt | ModelKind::MoeLlama7B => {
                        let mut cfg = if *kind == ModelKind::MoeGpt {
                            MoeGptConfig::moe_gpt_small()
                        } else {
                            MoeGptConfig::moe_llama_7b()
                        };
                        if let Some(l) = layers {
                            cfg.n_layer = *l;
                        }
                        if let Some(h) = hidden {
                            cfg.d_model = *h;
                        }
                        if let Some(e) = experts {
                            cfg.n_expert = *e;
                        }
                        check(cfg.d_model, cfg.n_head)?;
                        if cfg.n_expert == 0 || cfg.seq % cfg.n_expert != 0 {
                            return Err(Error::Config(format!(
                                "--experts {}: must divide the sequence length {}",
                                cfg.n_expert, cfg.seq
                            )));
                        }
                        Ok(moe_gpt(cfg, batch))
                    }
                    _ => Err(Error::Config(format!(
                        "{}: size overrides (--layers/--hidden/--experts) only \
                         apply to the GPT and MoE families",
                        kind.name()
                    ))),
                }
            }
            ModelSpec::File { text, .. } => import::import_json(text, batch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_presets_match_modelkind_identity() {
        for &m in ModelKind::all() {
            let spec = ModelSpec::preset(m);
            assert_eq!(spec.name(), m.name());
            for batch in [1usize, 8, 512] {
                assert_eq!(spec.graph_key(batch), m.graph_key(batch));
            }
        }
    }

    #[test]
    fn overrides_change_the_key_and_the_graph() {
        let base = ModelSpec::preset(ModelKind::Gpt2);
        let small = ModelSpec::Preset {
            kind: ModelKind::Gpt2,
            layers: Some(2),
            hidden: None,
            experts: None,
        };
        assert_ne!(base.graph_key(8), small.graph_key(8));
        let g = small.build(8).unwrap();
        assert!(g.validate().is_empty());
        assert!(g.num_params() < base.build(8).unwrap().num_params());
    }

    #[test]
    fn expert_override_resizes_the_moe_layer() {
        let spec = ModelSpec::Preset {
            kind: ModelKind::MoeGpt,
            layers: Some(2),
            hidden: None,
            experts: Some(4),
        };
        let g = spec.build(4).unwrap();
        assert_eq!(g.expert_capacity(), Some(4));
    }

    #[test]
    fn knobs_rejected_off_family() {
        let spec = ModelSpec::Preset {
            kind: ModelKind::ResNet50,
            layers: Some(2),
            hidden: None,
            experts: None,
        };
        assert!(spec.build(8).is_err());
        let spec = ModelSpec::Preset {
            kind: ModelKind::Gpt2,
            layers: None,
            hidden: None,
            experts: Some(4),
        };
        assert!(spec.build(8).is_err());
    }

    #[test]
    fn indivisible_hidden_rejected() {
        let spec = ModelSpec::Preset {
            kind: ModelKind::Gpt2,
            layers: None,
            hidden: Some(770), // not divisible by 12 heads
            experts: None,
        };
        assert!(spec.build(8).is_err());
    }

    #[test]
    fn parse_accepts_every_alias() {
        for a in ModelKind::aliases() {
            assert!(ModelSpec::parse(a).is_some());
        }
        assert!(ModelSpec::parse("bogus").is_none());
    }
}
