//! Model zoo: the six benchmark DNNs of the paper's evaluation
//! (Table II), built with the layer-level graph IR.
//!
//! | Task           | Model        | #Params |
//! |----------------|--------------|---------|
//! | Vision         | ResNet-50    | 25.6 M  |
//! | Vision         | Inception-V3 | 23.8 M  |
//! | Vision         | VGG-19       | 144 M   |
//! | NLP            | GPT-2        | 117 M   |
//! | NLP            | GPT-1.5B     | 1.5 B   |
//! | Recommendation | DLRM         | 516 M   |
//!
//! plus two Mixture-of-Experts GPT variants (MoE-GPT and a LLaMA-shaped
//! MoE-LLaMA-7B flagship) exercising expert parallelism, and a JSON
//! layer-graph importer ([`import`]) for external workloads.
//!
//! All models use synthetic data shapes (the paper evaluates with
//! synthetic datasets; data loading is out of scope). Parameter counts
//! are asserted against the reference implementations in the test suite.
//!
//! Call sites select workloads through [`ModelSpec`] — an open union of
//! built-in presets (with optional size-override knobs) and external
//! graph files — rather than matching on [`ModelKind`] directly.

pub mod dlrm;
pub mod gpt;
pub mod import;
pub mod inception;
pub mod moe;
pub mod resnet;
mod spec;
pub mod vgg;

pub use dlrm::{dlrm, DlrmConfig};
pub use gpt::{gpt2, GptConfig};
pub use inception::inception_v3;
pub use moe::{moe_gpt, MoeGptConfig};
pub use resnet::resnet50;
pub use spec::ModelSpec;
pub use vgg::vgg19;

use crate::graph::Graph;

/// Model selector for CLI / bench drivers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// ResNet-50 on 224×224 images.
    ResNet50,
    /// Inception-V3 on 299×299 images.
    InceptionV3,
    /// VGG-19 on 224×224 images.
    Vgg19,
    /// GPT-2 117M, sequence length 1024.
    Gpt2,
    /// GPT-2 XL scale (1.5B), sequence length 1024.
    Gpt15B,
    /// DLRM with 26 embedding tables.
    Dlrm,
    /// MoE GPT: the GPT-2 trunk with 8 experts in alternating blocks.
    MoeGpt,
    /// LLaMA-7B-shaped MoE flagship (32 × 4096, 8 experts).
    MoeLlama7B,
}

impl ModelKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "resnet50" | "resnet" => Some(ModelKind::ResNet50),
            "inception_v3" | "inception" => Some(ModelKind::InceptionV3),
            "vgg19" | "vgg" => Some(ModelKind::Vgg19),
            "gpt2" | "gpt-2" => Some(ModelKind::Gpt2),
            "gpt1.5b" | "gpt-1.5b" | "gpt15b" => Some(ModelKind::Gpt15B),
            "dlrm" => Some(ModelKind::Dlrm),
            "moe-gpt" | "moe_gpt" => Some(ModelKind::MoeGpt),
            "moe-llama-7b" | "moe_llama_7b" => Some(ModelKind::MoeLlama7B),
            _ => None,
        }
    }

    /// Every spelling [`ModelKind::parse`] accepts, in `all()` order with
    /// canonical names first. The help-audit test checks each appears in
    /// the CLI `HELP` text and the README.
    pub fn aliases() -> &'static [&'static str] {
        &[
            "resnet50",
            "resnet",
            "inception_v3",
            "inception",
            "vgg19",
            "vgg",
            "gpt2",
            "gpt-2",
            "gpt1.5b",
            "gpt-1.5b",
            "gpt15b",
            "dlrm",
            "moe-gpt",
            "moe_gpt",
            "moe-llama-7b",
            "moe_llama_7b",
        ]
    }

    /// Display name matching the paper's tables.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::ResNet50 => "ResNet50",
            ModelKind::InceptionV3 => "Inception_V3",
            ModelKind::Vgg19 => "VGG19",
            ModelKind::Gpt2 => "GPT-2",
            ModelKind::Gpt15B => "GPT-1.5B",
            ModelKind::Dlrm => "DLRM",
            ModelKind::MoeGpt => "MoE-GPT",
            ModelKind::MoeLlama7B => "MoE-LLaMA-7B",
        }
    }

    /// Build the model at a given global batch size.
    pub fn build(self, batch: usize) -> Graph {
        match self {
            ModelKind::ResNet50 => resnet50(batch),
            ModelKind::InceptionV3 => inception_v3(batch),
            ModelKind::Vgg19 => vgg19(batch),
            ModelKind::Gpt2 => gpt2(GptConfig::gpt2_117m(), batch),
            ModelKind::Gpt15B => gpt2(GptConfig::gpt2_1_5b(), batch),
            ModelKind::Dlrm => dlrm(DlrmConfig::paper_516m(), batch),
            ModelKind::MoeGpt => moe_gpt(MoeGptConfig::moe_gpt_small(), batch),
            ModelKind::MoeLlama7B => moe_gpt(MoeGptConfig::moe_llama_7b(), batch),
        }
    }

    /// Stable identity of the `(model, batch)` graph this kind builds,
    /// for keying cross-request caches (the [`crate::compiler::TemplateCache`]
    /// via [`crate::session::Session`] and the sweep runner). FNV-1a over
    /// the display name mixed with the batch, so the key survives enum
    /// reordering and is identical across processes — unlike the
    /// dedup-index keys the sweep runner used before the session layer.
    pub fn graph_key(self, batch: usize) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.name().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h ^ (batch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// All models, in the paper's table order.
    pub fn all() -> &'static [ModelKind] {
        &[
            ModelKind::ResNet50,
            ModelKind::InceptionV3,
            ModelKind::Vgg19,
            ModelKind::Gpt2,
            ModelKind::Gpt15B,
            ModelKind::Dlrm,
            ModelKind::MoeGpt,
            ModelKind::MoeLlama7B,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        assert_eq!(ModelKind::parse("resnet50"), Some(ModelKind::ResNet50));
        assert_eq!(ModelKind::parse("Inception_V3"), Some(ModelKind::InceptionV3));
        assert_eq!(ModelKind::parse("VGG19"), Some(ModelKind::Vgg19));
        assert_eq!(ModelKind::parse("gpt-2"), Some(ModelKind::Gpt2));
        assert_eq!(ModelKind::parse("GPT-1.5B"), Some(ModelKind::Gpt15B));
        assert_eq!(ModelKind::parse("dlrm"), Some(ModelKind::Dlrm));
        assert_eq!(ModelKind::parse("moe-gpt"), Some(ModelKind::MoeGpt));
        assert_eq!(
            ModelKind::parse("MoE-LLaMA-7B"),
            Some(ModelKind::MoeLlama7B)
        );
        assert_eq!(ModelKind::parse("nope"), None);
    }

    /// Every kind's display name, lowercased, is an accepted spelling —
    /// so `--model $(proteus info ... name)` round-trips.
    #[test]
    fn names_roundtrip_through_parse() {
        for &m in ModelKind::all() {
            assert_eq!(ModelKind::parse(&m.name().to_lowercase()), Some(m));
        }
    }

    /// `aliases()` is exactly the set `parse` accepts: each alias parses,
    /// and each kind is reachable from at least one alias.
    #[test]
    fn aliases_are_exhaustive_and_valid() {
        for a in ModelKind::aliases() {
            assert!(ModelKind::parse(a).is_some(), "alias '{a}' rejected");
        }
        for &m in ModelKind::all() {
            assert!(
                ModelKind::aliases()
                    .iter()
                    .any(|a| ModelKind::parse(a) == Some(m)),
                "{} unreachable from aliases()",
                m.name()
            );
        }
    }

    #[test]
    fn all_models_build_and_validate_small_batch() {
        for &m in ModelKind::all() {
            let g = m.build(8);
            assert!(g.validate().is_empty(), "{} invalid", m.name());
            assert!(!g.layers.is_empty());
        }
    }

    /// Table II parameter counts (±8% tolerance: our IR models layers at
    /// coarse granularity and omits some odds and ends).
    #[test]
    fn parameter_counts_match_table2() {
        let checks: &[(ModelKind, f64)] = &[
            (ModelKind::ResNet50, 25.6e6),
            (ModelKind::InceptionV3, 23.8e6),
            (ModelKind::Vgg19, 143.7e6),
            (ModelKind::Gpt2, 117e6),
            (ModelKind::Gpt15B, 1.5e9),
            (ModelKind::Dlrm, 516e6),
        ];
        for &(m, want) in checks {
            let got = m.build(8).num_params() as f64;
            let err = (got - want).abs() / want;
            assert!(
                err < 0.08,
                "{}: {got:.3e} params, want ≈{want:.3e} ({:.1}% off)",
                m.name(),
                err * 100.0
            );
        }
    }

    /// Every model's layer count and FLOPs should scale sanely.
    #[test]
    fn flops_scale_with_batch() {
        for &m in [ModelKind::ResNet50, ModelKind::Gpt2].iter() {
            let f8 = m.build(8).total_fwd_flops() as f64;
            let f16 = m.build(16).total_fwd_flops() as f64;
            let ratio = f16 / f8;
            assert!(
                (ratio - 2.0).abs() < 0.05,
                "{}: flops ratio {ratio}",
                m.name()
            );
        }
    }
}
