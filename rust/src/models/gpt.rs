//! GPT-2 family (117M and 1.5B) transformer language models.
//!
//! Architecture follows the GPT-2 reference: token embedding, `n_layer`
//! pre-norm transformer blocks (LN → QKV → attention → output projection
//! → residual; LN → 4× MLP → residual), final LayerNorm, and a weight-
//! untied LM head folded into the vocabulary projection.
//!
//! Megatron-style model parallelism falls out of the layer hints: the
//! QKV projection is head/column-split, the output projection and second
//! MLP linear are row-split (partial outputs → all-reduce), and the
//! embedding is vocabulary-split.

use crate::graph::{DType, Graph, GraphBuilder, MpHint};

/// GPT model hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GptConfig {
    /// Transformer blocks.
    pub n_layer: usize,
    /// Model width.
    pub d_model: usize,
    /// Attention heads.
    pub n_head: usize,
    /// Sequence length.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

impl GptConfig {
    /// GPT-2 small: 117M parameters (12 × 768, 12 heads).
    pub fn gpt2_117m() -> Self {
        GptConfig {
            n_layer: 12,
            d_model: 768,
            n_head: 12,
            seq: 1024,
            vocab: 50257,
        }
    }

    /// GPT-2 XL: 1.5B parameters (48 × 1600, 25 heads).
    pub fn gpt2_1_5b() -> Self {
        GptConfig {
            n_layer: 48,
            d_model: 1600,
            n_head: 25,
            seq: 1024,
            vocab: 50257,
        }
    }

    /// A tiny config for fast tests.
    pub fn tiny() -> Self {
        GptConfig {
            n_layer: 2,
            d_model: 64,
            n_head: 4,
            seq: 32,
            vocab: 1000,
        }
    }

    /// Approximate parameter count (12 h² per block + embeddings).
    pub fn approx_params(&self) -> u64 {
        let h = self.d_model as u64;
        let blocks = self.n_layer as u64 * 12 * h * h;
        let emb = (self.vocab as u64 + self.seq as u64) * h;
        blocks + emb
    }
}

/// Build a GPT-2 style model at `batch` sequences per step.
pub fn gpt2(cfg: GptConfig, batch: usize) -> Graph {
    let mut b = GraphBuilder::new("gpt2", batch);
    let h = cfg.d_model;
    let tokens = b.input("tokens", &[batch, cfg.seq], DType::I64);
    // Token + (learned) position embeddings; wpe is folded into wte's
    // layer as an extra elementwise add of a learned table.
    let mut x = b.scoped("embed", |b| {
        let e = b.embedding("wte", tokens, cfg.vocab, h, DType::F32);
        // Positional embedding is tiny (seq × h); modeled as an
        // elementwise add so the residual stream shape is preserved.
        b.elementwise("wpe_add", crate::graph::OpKind::Elementwise, &[e], 1.0, 1.0)
    });
    for i in 0..cfg.n_layer {
        x = b.scoped(&format!("block{i}"), |b| {
            // Attention sub-block.
            let ln1 = b.layer_norm("ln1", x);
            let qkv = b.qkv_proj("qkv", ln1, h, cfg.n_head);
            let att = b.attention("attn", qkv);
            let proj = b.out_proj("proj", att, h);
            let x1 = b.add("res1", x, proj);
            // MLP sub-block.
            let ln2 = b.layer_norm("ln2", x1);
            let fc1 = b.linear("fc1", ln2, h, 4 * h);
            let gelu = b.relu("gelu", fc1);
            // Megatron keeps the GeLU sharded along the 4h axis between
            // the column-parallel fc1 and row-parallel fc2 — no gather.
            b.hint_last(MpHint::LastDim);
            let fc2 = b.linear("fc2", gelu, 4 * h, h);
            b.hint_last(MpHint::RowSplit);
            b.add("res2", x1, fc2)
        });
    }
    b.scoped("head", |b| {
        let lnf = b.layer_norm("ln_f", x);
        // Weight-tied LM head: reuse the embedding table (the GPT-2
        // convention behind the 117M/1.5B parameter counts).
        let wte = b
            .find_tensor("embed.wte.weight")
            .expect("embedding table exists");
        let logits = b.linear_shared("lm_head", lnf, h, cfg.vocab, wte);
        let _ = b.loss("loss", logits);
    });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn gpt2_small_params_near_117m() {
        let g = gpt2(GptConfig::gpt2_117m(), 8);
        let p = g.num_params() as f64;
        let err = (p - 117e6).abs() / 117e6;
        assert!(err < 0.08, "params {p:.3e}");
    }

    #[test]
    fn lm_head_shares_the_embedding_table() {
        let g = gpt2(GptConfig::tiny(), 4);
        let head = g.layers.iter().find(|l| l.name == "lm_head").unwrap();
        let wte = g
            .tensors
            .iter()
            .find(|t| t.name == "embed.wte.weight")
            .unwrap();
        assert_eq!(head.params[0].tensor, wte.id);
    }

    #[test]
    fn block_structure_repeats() {
        let cfg = GptConfig::tiny();
        let g = gpt2(cfg, 4);
        let attn_layers = g
            .layers
            .iter()
            .filter(|l| l.kind == OpKind::Attention)
            .count();
        assert_eq!(attn_layers, cfg.n_layer);
        // Rowsplit hints on proj + fc2 per block.
        let rowsplit = g
            .layers
            .iter()
            .filter(|l| l.mp_hint == MpHint::RowSplit)
            .count();
        assert_eq!(rowsplit, 2 * cfg.n_layer);
    }

    #[test]
    fn residual_stream_shape_is_stable() {
        let cfg = GptConfig::tiny();
        let g = gpt2(cfg, 4);
        for l in &g.layers {
            if l.name == "res2" {
                let out = &g.tensors[l.outputs[0].tensor];
                assert_eq!(out.shape, vec![4, cfg.seq, cfg.d_model]);
            }
        }
    }

    #[test]
    fn flops_dominated_by_matmuls() {
        let g = gpt2(GptConfig::tiny(), 4);
        let total = g.total_fwd_flops() as f64;
        let linear: u64 = g
            .layers
            .iter()
            .filter(|l| l.kind == OpKind::Linear)
            .map(|l| l.fwd_flops())
            .sum();
        assert!(linear as f64 / total > 0.6);
    }
}
