//! VGG-19 (configuration E): 16 conv layers + 3 fully connected.
//!
//! The heavy, communication-hungry classifier (fc6/fc7 at 4096 wide,
//! ≈124M of the ≈144M parameters) is what makes VGG-19 the paper's
//! canonical comp-comm-overlap stress test (§VIII-D): gradient
//! all-reduce of the FC weights overlaps the convolutional backward
//! pass.

use crate::graph::{DType, Graph, GraphBuilder, TensorId};

/// Conv stage: `n` 3×3 same-pad convs at `c_out` channels, then 2×2 pool.
fn stage(
    b: &mut GraphBuilder,
    name: &str,
    mut x: TensorId,
    mut c_in: usize,
    c_out: usize,
    n: usize,
    hw: (usize, usize),
) -> (TensorId, (usize, usize)) {
    b.push_scope(name);
    let mut cur_hw = hw;
    for i in 0..n {
        let (y, nhw) = b.conv2d(&format!("conv{i}"), x, c_in, c_out, cur_hw, 3, 1, 1);
        cur_hw = nhw;
        x = b.batch_norm(&format!("bn{i}"), y);
        x = b.relu(&format!("relu{i}"), x);
        c_in = c_out;
    }
    let pooled_hw = (cur_hw.0 / 2, cur_hw.1 / 2);
    let x = b.pool("pool", x, pooled_hw.0 * pooled_hw.1);
    b.pop_scope();
    (x, pooled_hw)
}

/// Build VGG-19 for 224×224×3 inputs and 1000 classes.
pub fn vgg19(batch: usize) -> Graph {
    let mut b = GraphBuilder::new("vgg19", batch);
    let x = b.input("images", &[batch, 3, 224 * 224], DType::F32);
    let (x, hw) = stage(&mut b, "stage1", x, 3, 64, 2, (224, 224));
    let (x, hw) = stage(&mut b, "stage2", x, 64, 128, 2, hw);
    let (x, hw) = stage(&mut b, "stage3", x, 128, 256, 4, hw);
    let (x, hw) = stage(&mut b, "stage4", x, 256, 512, 4, hw);
    let (x, hw) = stage(&mut b, "stage5", x, 512, 512, 4, hw);
    assert_eq!(hw, (7, 7));
    b.scoped("classifier", |b| {
        let flat = b.flatten("flatten", x);
        let h = b.linear("fc6", flat, 512 * 7 * 7, 4096);
        let h = b.relu("relu6", h);
        let h = b.linear("fc7", h, 4096, 4096);
        // Megatron-style column/row alternation: under model parallelism
        // fc7 partitions its reduction dimension (the paper's S2 for
        // VGG19 "partitions data, output channels and reduction
        // dimensions" — which is what pushes it outside FlexFlow's SOAP
        // space, Table IV ✗).
        b.hint_last(crate::graph::MpHint::RowSplit);
        let h = b.relu("relu7", h);
        let logits = b.linear("fc8", h, 4096, 1000);
        let _ = b.loss("loss", logits);
    });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn vgg19_has_16_convs_and_3_fcs() {
        let g = vgg19(8);
        let convs = g.layers.iter().filter(|l| l.kind == OpKind::Conv2d).count();
        let fcs = g.layers.iter().filter(|l| l.kind == OpKind::Linear).count();
        assert_eq!(convs, 16);
        assert_eq!(fcs, 3);
    }

    #[test]
    fn classifier_holds_most_parameters() {
        let g = vgg19(8);
        let fc_params: u64 = g
            .layers
            .iter()
            .filter(|l| l.kind == OpKind::Linear)
            .flat_map(|l| l.params.iter())
            .map(|p| g.tensors[p.tensor].numel())
            .sum();
        assert!(fc_params as f64 / g.num_params() as f64 > 0.8);
    }

    #[test]
    fn convs_hold_most_flops() {
        let g = vgg19(8);
        let conv: u64 = g
            .layers
            .iter()
            .filter(|l| l.kind == OpKind::Conv2d)
            .map(|l| l.fwd_flops())
            .sum();
        assert!(conv as f64 / g.total_fwd_flops() as f64 > 0.9);
    }

    #[test]
    fn total_fwd_flops_near_reference() {
        // VGG-19 forward ≈ 19.6 GFLOPs/image (multiply-add counted as 2).
        let g = vgg19(1);
        let gf = g.total_fwd_flops() as f64 / 1e9;
        assert!((gf - 39.0).abs() / 39.0 < 0.15, "got {gf} GFLOP");
    }
}
