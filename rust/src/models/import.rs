//! Minimal JSON layer-graph importer (`--model-file`, [`super::ModelSpec::File`]).
//!
//! The format covers sequential feed-forward workloads — enough to bring
//! an external model into the simulator without writing Rust:
//!
//! ```json
//! {
//!   "name": "mlp4",
//!   "input": [512],
//!   "layers": [
//!     {"op": "linear", "out": 1024},
//!     {"op": "relu"},
//!     {"op": "layer_norm"},
//!     {"op": "linear", "out": 10},
//!     {"op": "loss"}
//!   ]
//! }
//! ```
//!
//! - `input`: feature dims after the batch axis — `[f]` builds a
//!   `[batch, f]` input, `[s, f]` a `[batch, s, f]` sequence input.
//! - `layers`: applied in order; each consumes the previous output.
//!   Ops: `linear` (required key `out`), `relu`, `layer_norm`, `loss`.
//! - A final `loss` is appended automatically if the file omits it, so
//!   the compiler always has a backward root.
//!
//! The global batch size stays a simulation-time parameter (like the
//! built-in presets); the file describes only the per-sample shapes.
//! Layer names are `l0..lN`, so strategy trees address imported layers
//! by position.

use crate::graph::{DType, Graph, GraphBuilder};
use crate::util::json::Json;
use crate::{Error, Result};

fn cfg_err(msg: String) -> Error {
    Error::Config(format!("model file: {msg}"))
}

/// Parse a JSON layer-graph document and build it at `batch`.
pub fn import_json(text: &str, batch: usize) -> Result<Graph> {
    let doc = Json::parse(text).map_err(|e| cfg_err(e.to_string()))?;
    let name = doc
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or("imported")
        .to_string();
    let input: Vec<usize> = doc
        .get("input")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| cfg_err("missing 'input' array".into()))?
        .iter()
        .map(|v| v.as_usize().filter(|&d| d > 0))
        .collect::<Option<_>>()
        .ok_or_else(|| cfg_err("'input' entries must be positive integers".into()))?;
    if input.is_empty() || input.len() > 2 {
        return Err(cfg_err(format!(
            "'input' must list 1 or 2 feature dims (after batch), got {}",
            input.len()
        )));
    }
    let layers = doc
        .get("layers")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| cfg_err("missing 'layers' array".into()))?;
    if layers.is_empty() {
        return Err(cfg_err("'layers' is empty".into()));
    }

    let mut b = GraphBuilder::new(&name, batch);
    let mut shape = vec![batch];
    shape.extend(&input);
    let mut cur = b.input("x", &shape, DType::F32);
    let mut width = *input.last().unwrap();
    let mut has_loss = false;
    for (i, l) in layers.iter().enumerate() {
        if has_loss {
            return Err(cfg_err(format!("layer {i}: ops after 'loss'")));
        }
        let op = l
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| cfg_err(format!("layer {i}: missing 'op'")))?;
        let lname = format!("l{i}");
        match op {
            "linear" => {
                let out = l
                    .get("out")
                    .and_then(|v| v.as_usize())
                    .filter(|&o| o > 0)
                    .ok_or_else(|| {
                        cfg_err(format!("layer {i}: linear needs a positive 'out'"))
                    })?;
                cur = b.linear(&lname, cur, width, out);
                width = out;
            }
            "relu" => cur = b.relu(&lname, cur),
            "layer_norm" => cur = b.layer_norm(&lname, cur),
            "loss" => {
                cur = b.loss(&lname, cur);
                has_loss = true;
            }
            other => {
                return Err(cfg_err(format!(
                    "layer {i}: unknown op '{other}' (expected linear|relu|layer_norm|loss)"
                )))
            }
        }
    }
    if !has_loss {
        let _ = b.loss("loss", cur);
    }
    // `finish` re-validates the structural invariants; all paths above go
    // through checked builder helpers, so this cannot panic on user input.
    Ok(b.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    const MLP: &str = r#"{
        "name": "mlp4",
        "input": [512],
        "layers": [
            {"op": "linear", "out": 1024},
            {"op": "relu"},
            {"op": "layer_norm"},
            {"op": "linear", "out": 10},
            {"op": "loss"}
        ]
    }"#;

    #[test]
    fn imports_an_mlp() {
        let g = import_json(MLP, 16).unwrap();
        assert_eq!(g.name, "mlp4");
        assert_eq!(g.batch_size, 16);
        assert_eq!(g.layers.len(), 5);
        // 512*1024 + 1024 (+ LN affine) + 1024*10 + 10
        assert!(g.num_params() >= 512 * 1024 + 1024 + 1024 * 10 + 10);
    }

    #[test]
    fn loss_is_appended_when_missing() {
        let src = r#"{"name":"m","input":[8],"layers":[{"op":"linear","out":4}]}"#;
        let g = import_json(src, 4).unwrap();
        assert_eq!(g.layers.last().unwrap().name, "loss");
    }

    #[test]
    fn sequence_inputs_build_3d_graphs() {
        let src = r#"{"input":[32, 64],"layers":[{"op":"linear","out":16}]}"#;
        let g = import_json(src, 4).unwrap();
        assert_eq!(g.name, "imported");
        let out = &g.tensors[g.layers[0].outputs[0].tensor];
        assert_eq!(out.shape, vec![4, 32, 16]);
    }

    #[test]
    fn bad_documents_are_rejected() {
        assert!(import_json("not json", 4).is_err());
        assert!(import_json(r#"{"layers":[{"op":"relu"}]}"#, 4).is_err());
        assert!(import_json(r#"{"input":[8],"layers":[]}"#, 4).is_err());
        assert!(import_json(r#"{"input":[8],"layers":[{"op":"conv9"}]}"#, 4).is_err());
        assert!(import_json(r#"{"input":[8],"layers":[{"op":"linear"}]}"#, 4).is_err());
        assert!(import_json(
            r#"{"input":[8],"layers":[{"op":"loss"},{"op":"relu"}]}"#,
            4
        )
        .is_err());
    }

    #[test]
    fn batch_scales_flops_linearly() {
        let f4 = import_json(MLP, 4).unwrap().total_fwd_flops() as f64;
        let f8 = import_json(MLP, 8).unwrap().total_fwd_flops() as f64;
        assert!((f8 / f4 - 2.0).abs() < 0.05);
    }
}
