//! Mixture-of-Experts GPT variants (expert-parallel workloads).
//!
//! The dense trunk follows `models/gpt.rs` exactly; in MoE blocks the
//! 4× MLP is replaced by a routed expert FFN:
//!
//! - a dense **router** linear scoring each token against the experts,
//! - [`crate::graph::GraphBuilder::moe_dispatch`] permuting tokens into
//!   per-expert capacity buckets `[b, e, k, m]` (top-1 routing at exact
//!   capacity `k = seq / n_expert`),
//! - two per-expert linears ([`moe_expert_linear`]) whose `[e, o, h]`
//!   weights carry the expert axis — partitioning `e` is expert
//!   parallelism (the expert activation is folded into the dispatch /
//!   combine elementwise costs; it is bandwidth-trivial next to the
//!   expert matmuls),
//! - [`moe_combine`] un-permuting the buckets back into the sequence.
//!
//! Under an `ep > 1` strategy the dispatch→expert and expert→combine
//! boundaries re-shard from token-parallel to expert-parallel layouts,
//! which the transformation pass lowers to `AllToAll` collectives — the
//! defining communication pattern of expert parallelism.
//!
//! [`moe_expert_linear`]: crate::graph::GraphBuilder::moe_expert_linear
//! [`moe_combine`]: crate::graph::GraphBuilder::moe_combine

use crate::graph::{DType, Graph, GraphBuilder, MpHint};

/// MoE GPT hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct MoeGptConfig {
    /// Transformer blocks.
    pub n_layer: usize,
    /// Model width.
    pub d_model: usize,
    /// Attention heads.
    pub n_head: usize,
    /// Sequence length.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Expert FFN hidden width.
    pub d_ff: usize,
    /// Experts per MoE layer. Must divide `seq` (exact-capacity top-1
    /// routing).
    pub n_expert: usize,
    /// Every `moe_every`-th block uses the expert FFN (1 = all blocks,
    /// 2 = alternating as in GShard/Switch).
    pub moe_every: usize,
}

impl MoeGptConfig {
    /// MoE-GPT small: the GPT-2 117M trunk with 8 experts in
    /// alternating blocks.
    pub fn moe_gpt_small() -> Self {
        MoeGptConfig {
            n_layer: 12,
            d_model: 768,
            n_head: 12,
            seq: 1024,
            vocab: 50257,
            d_ff: 3072,
            n_expert: 8,
            moe_every: 2,
        }
    }

    /// LLaMA-7B-shaped flagship: 32 × 4096, 32 heads, seq 2048, 32k
    /// vocabulary, 11008-wide FFN — with 8 experts in alternating
    /// blocks (Mixtral-style scale-out of the 7B trunk).
    pub fn moe_llama_7b() -> Self {
        MoeGptConfig {
            n_layer: 32,
            d_model: 4096,
            n_head: 32,
            seq: 2048,
            vocab: 32000,
            d_ff: 11008,
            n_expert: 8,
            moe_every: 2,
        }
    }

    /// A tiny config for fast tests (every block MoE, 4 experts).
    pub fn tiny() -> Self {
        MoeGptConfig {
            n_layer: 2,
            d_model: 64,
            n_head: 4,
            seq: 32,
            vocab: 1000,
            d_ff: 256,
            n_expert: 4,
            moe_every: 1,
        }
    }

    /// Approximate parameter count: attention (4h²) every block, dense
    /// FFN (2·h·ff) in dense blocks, `n_expert`-wide FFN + router in
    /// MoE blocks, plus the embeddings.
    pub fn approx_params(&self) -> u64 {
        let h = self.d_model as u64;
        let ff = self.d_ff as u64;
        let mut total = (self.vocab as u64 + self.seq as u64) * h;
        for i in 0..self.n_layer {
            total += 4 * h * h; // attention
            if (i + 1) % self.moe_every == 0 {
                total += self.n_expert as u64 * 2 * h * ff + h * self.n_expert as u64;
            } else {
                total += 2 * h * ff;
            }
        }
        total
    }
}

/// Build an MoE GPT model at `batch` sequences per step.
pub fn moe_gpt(cfg: MoeGptConfig, batch: usize) -> Graph {
    assert!(cfg.moe_every >= 1, "moe_every must be ≥ 1");
    assert_eq!(
        cfg.seq % cfg.n_expert,
        0,
        "seq {} must be divisible by n_expert {}",
        cfg.seq,
        cfg.n_expert
    );
    let mut b = GraphBuilder::new("moe_gpt", batch);
    let h = cfg.d_model;
    let tokens = b.input("tokens", &[batch, cfg.seq], DType::I64);
    let mut x = b.scoped("embed", |b| {
        let e = b.embedding("wte", tokens, cfg.vocab, h, DType::F32);
        b.elementwise("wpe_add", crate::graph::OpKind::Elementwise, &[e], 1.0, 1.0)
    });
    for i in 0..cfg.n_layer {
        let moe_block = (i + 1) % cfg.moe_every == 0;
        x = b.scoped(&format!("block{i}"), |b| {
            // Attention sub-block (identical to the dense GPT trunk).
            let ln1 = b.layer_norm("ln1", x);
            let qkv = b.qkv_proj("qkv", ln1, h, cfg.n_head);
            let att = b.attention("attn", qkv);
            let proj = b.out_proj("proj", att, h);
            let x1 = b.add("res1", x, proj);
            // FFN sub-block: routed experts or the dense MLP.
            let ln2 = b.layer_norm("ln2", x1);
            let out = if moe_block {
                let scores = b.linear("router", ln2, h, cfg.n_expert);
                let disp = b.moe_dispatch("dispatch", ln2, scores, cfg.n_expert);
                let fc1 = b.moe_expert_linear("fc1", disp, h, cfg.d_ff);
                let fc2 = b.moe_expert_linear("fc2", fc1, cfg.d_ff, h);
                b.moe_combine("combine", fc2)
            } else {
                let fc1 = b.linear("fc1", ln2, h, cfg.d_ff);
                let gelu = b.relu("gelu", fc1);
                b.hint_last(MpHint::LastDim);
                let fc2 = b.linear("fc2", gelu, cfg.d_ff, h);
                b.hint_last(MpHint::RowSplit);
                fc2
            };
            b.add("res2", x1, out)
        });
    }
    b.scoped("head", |b| {
        let lnf = b.layer_norm("ln_f", x);
        let wte = b
            .find_tensor("embed.wte.weight")
            .expect("embedding table exists");
        let logits = b.linear_shared("lm_head", lnf, h, cfg.vocab, wte);
        let _ = b.loss("loss", logits);
    });
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn tiny_moe_builds_and_validates() {
        let g = moe_gpt(MoeGptConfig::tiny(), 4);
        assert!(g.has_experts());
        // Every block MoE at moe_every = 1: 2 dispatch, 2 combine,
        // 4 expert linears.
        let dispatch = g.layers.iter().filter(|l| l.name == "dispatch").count();
        let combine = g.layers.iter().filter(|l| l.name == "combine").count();
        assert_eq!(dispatch, 2);
        assert_eq!(combine, 2);
        let expert_linears = g
            .layers
            .iter()
            .filter(|l| {
                l.kind == OpKind::Linear
                    && l.params
                        .iter()
                        .any(|p| p.axes.iter().any(|a| a.as_deref() == Some("e")))
            })
            .count();
        assert_eq!(expert_linears, 4);
    }

    #[test]
    fn alternating_blocks_keep_the_dense_mlp() {
        let cfg = MoeGptConfig::moe_gpt_small();
        let g = moe_gpt(cfg, 2);
        let dispatch = g.layers.iter().filter(|l| l.name == "dispatch").count();
        let gelu = g.layers.iter().filter(|l| l.name == "gelu").count();
        assert_eq!(dispatch, cfg.n_layer / 2);
        assert_eq!(gelu, cfg.n_layer / 2);
    }

    #[test]
    fn expert_weights_carry_the_expert_axis() {
        let cfg = MoeGptConfig::tiny();
        let g = moe_gpt(cfg, 4);
        let fc1 = g.layers.iter().find(|l| l.name == "fc1").unwrap();
        let w = &g.tensors[fc1.params[0].tensor];
        assert_eq!(w.shape, vec![cfg.n_expert, cfg.d_ff, cfg.d_model]);
        assert_eq!(fc1.params[0].axes[0].as_deref(), Some("e"));
    }

    #[test]
    fn param_count_tracks_the_closed_form() {
        for cfg in [MoeGptConfig::tiny(), MoeGptConfig::moe_gpt_small()] {
            let g = moe_gpt(cfg, 2);
            let p = g.num_params() as f64;
            let approx = cfg.approx_params() as f64;
            let err = (p - approx).abs() / approx;
            assert!(err < 0.10, "params {p:.3e} vs approx {approx:.3e}");
        }
    }

    #[test]
    fn capacity_times_experts_equals_seq() {
        let cfg = MoeGptConfig::tiny();
        let g = moe_gpt(cfg, 4);
        let d = g.layers.iter().find(|l| l.name == "dispatch").unwrap();
        let e = d.dim_size("e").unwrap();
        let k = d.dim_size("k").unwrap();
        assert_eq!(e * k, cfg.seq);
    }
}
