//! Minimal JSON codec (parser + pretty serializer).
//!
//! The build has no network access and `serde`/`serde_json` are not in the
//! vendored crate set, so the config system and Chrome-trace export use
//! this self-contained implementation. It supports the full JSON grammar
//! except for `\u` surrogate pairs outside the BMP (sufficient for config
//! files and traces, which are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (sorted keys) — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Interpret as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Interpret as u64 (must be a non-negative integer-valued number).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Interpret as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// Interpret as str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Interpret as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Interpret as array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Human-readable description.
    pub msg: String,
    /// Byte offset where the error occurred.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("non-utf8 in \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad hex in \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid UTF-8 byte")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated UTF-8 sequence"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid UTF-8 sequence"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-utf8 number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo → ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ok"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Bool(false), Json::Str("z\"q".into())])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(8.0).to_string_compact(), "8");
        assert_eq!(Json::Num(8.5).to_string_compact(), "8.5");
    }

    #[test]
    fn as_u64_rejects_fractional_and_negative() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }
}
