//! Small self-contained utilities: JSON, PRNG, time units, topological
//! sort, and formatting helpers.
//!
//! The build is fully offline (vendored crates only), so a handful of
//! things that would normally come from crates.io — a JSON codec, a
//! deterministic PRNG, a table formatter — live here instead.

pub mod json;
pub mod rng;
pub mod table;
pub mod time;
pub mod topo;

/// Integer division rounding up.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Product of a shape's dimensions (number of elements).
#[inline]
pub fn numel(shape: &[usize]) -> u64 {
    shape.iter().map(|&d| d as u64).product()
}

/// Mean of a slice of f64 (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Relative error |a-b| / |b| in percent; `b` is the reference value.
pub fn rel_err_pct(pred: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        if pred == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        ((pred - truth) / truth).abs() * 100.0
    }
}

/// Format a byte count in a human-readable way (MiB/GiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{} B", bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_exact_and_inexact() {
        assert_eq!(div_ceil(8, 4), 2);
        assert_eq!(div_ceil(9, 4), 3);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(0, 4), 0);
    }

    #[test]
    fn numel_basic() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(numel(&[]), 1);
    }

    #[test]
    fn rel_err_pct_signs_and_zero() {
        assert!((rel_err_pct(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert!((rel_err_pct(90.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(rel_err_pct(0.0, 0.0), 0.0);
        assert!(rel_err_pct(1.0, 0.0).is_infinite());
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
