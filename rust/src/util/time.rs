//! Simulation time units.
//!
//! All simulator-internal times are integer **picoseconds** (`Ps`) so that
//! discrete-event ordering is exact and reproducible; floats appear only
//! at the user-facing edges (milliseconds, samples/second).

/// Simulated time in integer picoseconds.
pub type Ps = u64;

/// One nanosecond in picoseconds.
pub const NS: Ps = 1_000;
/// One microsecond in picoseconds.
pub const US: Ps = 1_000_000;
/// One millisecond in picoseconds.
pub const MS: Ps = 1_000_000_000;
/// One second in picoseconds.
pub const SEC: Ps = 1_000_000_000_000;

/// Convert picoseconds to fractional milliseconds.
#[inline]
pub fn ps_to_ms(ps: Ps) -> f64 {
    ps as f64 / MS as f64
}

/// Convert fractional seconds to picoseconds (saturating at u64::MAX).
#[inline]
pub fn secs_to_ps(s: f64) -> Ps {
    if !s.is_finite() || s <= 0.0 {
        return 0;
    }
    let ps = s * SEC as f64;
    if ps >= u64::MAX as f64 {
        u64::MAX
    } else {
        ps as Ps
    }
}

/// Convert picoseconds to fractional seconds.
#[inline]
pub fn ps_to_secs(ps: Ps) -> f64 {
    ps as f64 / SEC as f64
}

/// Scale a duration by a float factor (e.g. the γ overlap penalty),
/// rounding to nearest and saturating.
#[inline]
pub fn scale(ps: Ps, factor: f64) -> Ps {
    debug_assert!(factor >= 0.0);
    let v = ps as f64 * factor;
    if v >= u64::MAX as f64 {
        u64::MAX
    } else {
        v.round() as Ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_secs() {
        let ps = secs_to_ps(1.5);
        assert_eq!(ps, 1_500_000_000_000);
        assert!((ps_to_secs(ps) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ms_conversion() {
        assert!((ps_to_ms(2 * MS) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn scale_rounds() {
        assert_eq!(scale(10, 1.26), 13); // 12.6 → 13
        assert_eq!(scale(10, 0.0), 0);
    }

    #[test]
    fn scale_saturates() {
        assert_eq!(scale(u64::MAX, 2.0), u64::MAX);
    }

    #[test]
    fn secs_to_ps_handles_garbage() {
        assert_eq!(secs_to_ps(-1.0), 0);
        assert_eq!(secs_to_ps(f64::NAN), 0);
        assert_eq!(secs_to_ps(f64::INFINITY), 0);
    }
}
