//! Plain-text table rendering for CLI / bench output.
//!
//! The bench harnesses print the same rows the paper's tables report;
//! this helper keeps that output aligned and diff-friendly.

/// A simple column-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity differs from the header.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
            }
            // Trim trailing spaces on the last column.
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        fmt_row(&self.header, &width, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &width, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "err%"]);
        t.row(vec!["ResNet50".into(), "2.1".into()]);
        t.row(vec!["GPT-2".into(), "12.34".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("ResNet50"));
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn empty_and_len() {
        let mut t = Table::new(&["a"]);
        assert!(t.is_empty());
        t.row(vec!["1".into()]);
        assert_eq!(t.len(), 1);
    }
}
