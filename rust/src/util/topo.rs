//! Topological ordering over index-based DAGs.
//!
//! The compiler and both simulators need topological traversals of
//! execution graphs where nodes are dense `usize` ids.

/// Kahn's algorithm over an adjacency list. `succs[i]` lists the
/// successors of node `i`. Returns `None` if the graph has a cycle.
pub fn topo_sort(n: usize, succs: &[Vec<usize>]) -> Option<Vec<usize>> {
    debug_assert_eq!(succs.len(), n);
    let mut indeg = vec![0usize; n];
    for ss in succs {
        for &s in ss {
            indeg[s] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    // Process in ascending id order for determinism: `queue` is kept as a
    // simple FIFO which preserves insertion (id) order well enough because
    // ids are assigned in construction order.
    let mut head = 0;
    let mut order = Vec::with_capacity(n);
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        order.push(u);
        for &v in &succs[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    if order.len() == n {
        Some(order)
    } else {
        None
    }
}

/// Check whether `order` is a valid topological order of the DAG.
pub fn is_topo_order(order: &[usize], succs: &[Vec<usize>]) -> bool {
    let n = succs.len();
    if order.len() != n {
        return false;
    }
    let mut pos = vec![usize::MAX; n];
    for (i, &u) in order.iter().enumerate() {
        if u >= n || pos[u] != usize::MAX {
            return false;
        }
        pos[u] = i;
    }
    for (u, ss) in succs.iter().enumerate() {
        for &v in ss {
            if pos[u] >= pos[v] {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_a_chain() {
        let succs = vec![vec![1], vec![2], vec![]];
        let order = topo_sort(3, &succs).unwrap();
        assert_eq!(order, vec![0, 1, 2]);
        assert!(is_topo_order(&order, &succs));
    }

    #[test]
    fn sorts_a_diamond() {
        // 0 -> {1,2} -> 3
        let succs = vec![vec![1, 2], vec![3], vec![3], vec![]];
        let order = topo_sort(4, &succs).unwrap();
        assert!(is_topo_order(&order, &succs));
    }

    #[test]
    fn detects_cycle() {
        let succs = vec![vec![1], vec![0]];
        assert!(topo_sort(2, &succs).is_none());
    }

    #[test]
    fn self_loop_is_a_cycle() {
        let succs = vec![vec![0]];
        assert!(topo_sort(1, &succs).is_none());
    }

    #[test]
    fn empty_graph() {
        assert_eq!(topo_sort(0, &[]).unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn validator_rejects_bad_orders() {
        let succs = vec![vec![1], vec![]];
        assert!(!is_topo_order(&[1, 0], &succs));
        assert!(!is_topo_order(&[0], &succs));
        assert!(!is_topo_order(&[0, 0], &succs));
    }
}
