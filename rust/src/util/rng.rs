//! Deterministic PRNG (xoshiro256**) used by the emulator's efficiency
//! ripple and by the in-tree property-testing framework.
//!
//! We deliberately do not use OS randomness anywhere: simulations must be
//! exactly reproducible given (model, strategy, cluster, seed).

/// xoshiro256** by Blackman & Vigna — small, fast, high quality, and easy
/// to reimplement bit-exactly in other languages if needed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that consecutive small seeds give unrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (rejection-free for
    /// our purposes; bias is < 2^-32 for bounds < 2^32 which is fine for
    /// test generation, and the emulator only uses `next_f64`).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_roughly_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn range_hits_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.range(0, 4)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
