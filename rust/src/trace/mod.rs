//! Timeline export: Chrome trace (chrome://tracing / Perfetto) JSON.
//!
//! Rows (`pid`) are devices; tracks (`tid`) are the three HTAE streams
//! (computation, feature communication, gradient communication), so the
//! exported trace visually reproduces the paper's Fig. 5a execution
//! timeline — comp-comm overlap and bandwidth sharing are directly
//! visible. Every duration event carries the task's pipeline `stage`,
//! `micro`-batch index, and `phase` in its `args`, so GPipe / 1F1B /
//! interleaved schedules are visually distinguishable in Perfetto
//! (select an event, or color by `args.micro`).

use crate::compiler::{CommClass, ExecGraph, Task, TaskKind};
use crate::executor::Span;
use crate::graph::Graph;
use crate::util::json::Json;

/// Stream (track) ids within a device row.
const TID_COMP: f64 = 0.0;
const TID_FEAT: f64 = 1.0;
const TID_GRAD: f64 = 2.0;

/// Render a simulated timeline as a Chrome trace JSON document.
pub fn chrome_trace(graph: &Graph, eg: &ExecGraph, timeline: &[Span]) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(timeline.len() + eg.n_devices * 3);
    // Track name metadata.
    for d in 0..eg.n_devices {
        for (tid, name) in [
            (TID_COMP, "compute"),
            (TID_FEAT, "feature comm"),
            (TID_GRAD, "gradient comm"),
        ] {
            events.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(d as f64)),
                ("tid", Json::Num(tid)),
                (
                    "args",
                    Json::obj(vec![("name", Json::Str(name.into()))]),
                ),
            ]));
        }
    }
    for span in timeline {
        let task = &eg.tasks[span.task];
        let ts = span.start as f64 / 1e6; // ps → µs
        let dur = (span.end - span.start) as f64 / 1e6;
        let name = task.label(graph);
        match &task.kind {
            TaskKind::Comp(c) => {
                events.push(duration_event(&name, c.device, TID_COMP, ts, dur, task));
            }
            TaskKind::Comm(c) => {
                let tid = match c.class {
                    CommClass::Feature => TID_FEAT,
                    CommClass::Gradient => TID_GRAD,
                };
                for &d in &c.group {
                    events.push(duration_event(&name, d, tid, ts, dur, task));
                }
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

fn duration_event(name: &str, pid: usize, tid: f64, ts: f64, dur: f64, task: &Task) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("X".into())),
        ("name", Json::Str(name.into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid)),
        ("ts", Json::Num(ts)),
        ("dur", Json::Num(dur)),
        (
            "args",
            Json::obj(vec![
                ("stage", Json::Num(task.stage as f64)),
                ("micro", Json::Num(task.micro as f64)),
                ("phase", Json::Str(format!("{:?}", task.phase))),
            ]),
        ),
    ])
}

/// Write a Chrome trace to a file.
pub fn write_chrome_trace(
    path: &str,
    graph: &Graph,
    eg: &ExecGraph,
    timeline: &[Span],
) -> crate::Result<()> {
    let json = chrome_trace(graph, eg, timeline);
    std::fs::write(path, json.to_string_compact())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Preset};
    use crate::estimator::OpEstimator;
    use crate::executor::{Htae, HtaeConfig};
    use crate::graph::{DType, GraphBuilder};
    use crate::strategy::{build_strategy, StrategySpec};

    #[test]
    fn trace_roundtrips_through_the_json_parser() {
        let mut b = GraphBuilder::new("m", 8);
        let x = b.input("x", &[8, 64], DType::F32);
        let h = b.linear("fc", x, 64, 64);
        let _ = b.loss("loss", h);
        let g = b.finish();
        let tree = build_strategy(&g, StrategySpec::data_parallel(2)).unwrap();
        let c = Cluster::preset(Preset::HC1, 1);
        let eg = crate::compiler::compile(&g, &tree, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        let r = Htae::with_config(
            &c,
            &est,
            HtaeConfig {
                record_timeline: true,
                ..HtaeConfig::default()
            },
        )
        .simulate(&eg)
        .unwrap();
        let doc = chrome_trace(&g, &eg, &r.timeline);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // Metadata + one event per comp task + per comm participant.
        assert!(events.len() > r.timeline.len());
        // Every duration event has non-negative dur and carries the
        // pipeline stage + micro-batch index in args (Perfetto needs
        // them to tell schedules apart).
        for e in events {
            if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                let args = e.get("args").expect("duration events carry args");
                assert!(args.get("stage").and_then(|v| v.as_f64()).is_some());
                assert!(args.get("micro").and_then(|v| v.as_f64()).is_some());
                assert!(args.get("phase").and_then(|v| v.as_str()).is_some());
            }
        }
    }
}
