//! Timeline export: Chrome trace (chrome://tracing / Perfetto) JSON.
//!
//! Rows (`pid`) are devices; tracks (`tid`) are the three HTAE streams
//! (computation, feature communication, gradient communication), so the
//! exported trace visually reproduces the paper's Fig. 5a execution
//! timeline — comp-comm overlap and bandwidth sharing are directly
//! visible. Every duration event carries the task's pipeline `stage`,
//! `micro`-batch index, and `phase` in its `args`, so GPipe / 1F1B /
//! interleaved schedules are visually distinguishable in Perfetto
//! (select an event, or color by `args.micro`).

use crate::compiler::{CommClass, ExecGraph, TaskRef, TaskView};
use crate::executor::{PhaseSpan, Span};
use crate::graph::Graph;
use crate::util::json::Json;

/// Stream (track) ids within a device row.
const TID_COMP: f64 = 0.0;
const TID_FEAT: f64 = 1.0;
const TID_GRAD: f64 = 2.0;
/// Collective plan phases render on their own track below the streams.
const TID_PHASE: f64 = 3.0;

/// Render a simulated timeline as a Chrome trace JSON document.
pub fn chrome_trace(graph: &Graph, eg: &ExecGraph, timeline: &[Span]) -> Json {
    chrome_trace_with_phases(graph, eg, timeline, &[])
}

/// Render a timeline plus the per-phase sub-spans of planned
/// collectives: each phase (`intra-rs`, `inter-ar`, `bcast-tree`, ...)
/// becomes a duration event on a dedicated "coll phases" track of every
/// participating device, so the Fig. 7 hierarchy traversal is directly
/// visible under the owning collective in Perfetto.
pub fn chrome_trace_with_phases(
    graph: &Graph,
    eg: &ExecGraph,
    timeline: &[Span],
    phases: &[PhaseSpan],
) -> Json {
    let mut events: Vec<Json> =
        Vec::with_capacity(timeline.len() + phases.len() + eg.n_devices * 4);
    // Track name metadata.
    for d in 0..eg.n_devices {
        for (tid, name) in [
            (TID_COMP, "compute"),
            (TID_FEAT, "feature comm"),
            (TID_GRAD, "gradient comm"),
            (TID_PHASE, "coll phases"),
        ] {
            events.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(d as f64)),
                ("tid", Json::Num(tid)),
                (
                    "args",
                    Json::obj(vec![("name", Json::Str(name.into()))]),
                ),
            ]));
        }
    }
    for span in timeline {
        let task = eg.view(span.task);
        let ts = span.start as f64 / 1e6; // ps → µs
        let dur = (span.end - span.start) as f64 / 1e6;
        let name = task.label(graph);
        match task.kind {
            TaskRef::Comp(c) => {
                events.push(duration_event(&name, c.device, TID_COMP, ts, dur, &task));
            }
            TaskRef::Comm(c) => {
                let tid = match c.class {
                    CommClass::Feature => TID_FEAT,
                    CommClass::Gradient => TID_GRAD,
                };
                for &d in &c.group {
                    events.push(duration_event(&name, d, tid, ts, dur, &task));
                }
            }
        }
    }
    for ph in phases {
        let task = eg.view(ph.task);
        let ts = ph.start as f64 / 1e6; // ps → µs
        let dur = (ph.end - ph.start) as f64 / 1e6;
        if let TaskRef::Comm(c) = task.kind {
            let name = format!("{}·{}", c.kind.name(), ph.label);
            for &d in &c.group {
                events.push(duration_event(&name, d, TID_PHASE, ts, dur, &task));
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

fn duration_event(
    name: &str,
    pid: usize,
    tid: f64,
    ts: f64,
    dur: f64,
    task: &TaskView<'_>,
) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("X".into())),
        ("name", Json::Str(name.into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid)),
        ("ts", Json::Num(ts)),
        ("dur", Json::Num(dur)),
        (
            "args",
            Json::obj(vec![
                ("stage", Json::Num(task.stage as f64)),
                ("micro", Json::Num(task.micro as f64)),
                ("phase", Json::Str(format!("{:?}", task.phase))),
            ]),
        ),
    ])
}

/// Write a Chrome trace (timeline + collective phase sub-spans) to a
/// file.
pub fn write_chrome_trace(
    path: &str,
    graph: &Graph,
    eg: &ExecGraph,
    timeline: &[Span],
    phases: &[PhaseSpan],
) -> crate::Result<()> {
    let json = chrome_trace_with_phases(graph, eg, timeline, phases);
    std::fs::write(path, json.to_string_compact())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, Preset};
    use crate::estimator::OpEstimator;
    use crate::executor::{Htae, HtaeConfig};
    use crate::graph::{DType, GraphBuilder};
    use crate::strategy::{build_strategy, StrategySpec};

    #[test]
    fn trace_roundtrips_through_the_json_parser() {
        let mut b = GraphBuilder::new("m", 8);
        let x = b.input("x", &[8, 64], DType::F32);
        let h = b.linear("fc", x, 64, 64);
        let _ = b.loss("loss", h);
        let g = b.finish();
        let tree = build_strategy(&g, StrategySpec::data_parallel(2)).unwrap();
        let c = Cluster::preset(Preset::HC1, 1);
        let eg = crate::compiler::compile(&g, &tree, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        let r = Htae::with_config(
            &c,
            &est,
            HtaeConfig {
                record_timeline: true,
                ..HtaeConfig::default()
            },
        )
        .simulate(&eg)
        .unwrap();
        let doc = chrome_trace_with_phases(&g, &eg, &r.timeline, &r.comm_phases);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // Metadata + one event per comp task + per comm participant.
        assert!(events.len() > r.timeline.len());
        // Every duration event has non-negative dur and carries the
        // pipeline stage + micro-batch index in args (Perfetto needs
        // them to tell schedules apart).
        for e in events {
            if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                let args = e.get("args").expect("duration events carry args");
                assert!(args.get("stage").and_then(|v| v.as_f64()).is_some());
                assert!(args.get("micro").and_then(|v| v.as_f64()).is_some());
                assert!(args.get("phase").and_then(|v| v.as_str()).is_some());
            }
        }
    }

    /// Planned collectives export their phase sub-spans: a cross-node
    /// all-reduce contributes `all_reduce·intra-rs` / `·inter-ar` /
    /// `·intra-ag` duration events on the phase track.
    #[test]
    fn trace_carries_collective_phase_events() {
        use crate::compiler::{CollectiveKind, CommTask, TaskKind};
        use crate::testing::{adhoc_exec_graph, adhoc_task};

        let mut b = GraphBuilder::new("m", 8);
        let x = b.input("x", &[8, 64], DType::F32);
        let h = b.linear("fc", x, 64, 64);
        let _ = b.loss("loss", h);
        let g = b.finish();
        let c = Cluster::preset(Preset::HC2, 2);
        let eg = adhoc_exec_graph(
            vec![adhoc_task(TaskKind::Comm(CommTask {
                kind: CollectiveKind::AllReduce,
                group: (0..16).collect(),
                bytes: 64 << 20,
                class: crate::compiler::CommClass::Gradient,
            }))],
            16,
        );
        let est = OpEstimator::analytical(&c);
        let r = Htae::with_config(
            &c,
            &est,
            HtaeConfig {
                record_timeline: true,
                ..HtaeConfig::plain()
            },
        )
        .simulate(&eg)
        .unwrap();
        assert!(!r.comm_phases.is_empty());
        let doc = chrome_trace_with_phases(&g, &eg, &r.timeline, &r.comm_phases);
        let text = doc.to_string_compact();
        assert!(text.contains("inter-ar"), "phase events must be exported");
        assert!(text.contains("coll phases"), "phase track must be named");
    }
}
