//! Runtime services: the AOT cost-kernel executor and the parallel
//! scenario [`SweepRunner`].
//!
//! ## PJRT cost kernel (`pjrt` feature)
//!
//! The production cost path loads an AOT-compiled JAX/Pallas kernel
//! (**HLO text**, not a serialized `HloModuleProto`: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that the pinned `xla_extension`
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
//! — see `python/compile/aot.py`) and executes it through the PJRT CPU
//! client. Python never runs at simulation time: `make artifacts` lowers
//! the JAX/Pallas cost model once; this module compiles the text at
//! startup and then executes batches of feature rows with no Python
//! involvement.
//!
//! The PJRT path needs the vendored `xla` bindings, which the offline
//! build environment does not ship. It is therefore gated behind the
//! `pjrt` cargo feature; the default build substitutes a stub
//! [`CostKernel`] whose `load` fails cleanly, so
//! `OpEstimator::best_available` falls back to the bit-faithful
//! analytical mirror and every other subsystem works unchanged.
//!
//! ## Scenario sweeps
//!
//! [`SweepRunner`] simulates batches of `(model, cluster, strategy)`
//! scenarios on a fixed thread pool, deduplicating the shared model
//! graph construction, and ranks the survivors by predicted throughput.
//! This is what makes large-scale strategy search (paper §I, Table 6)
//! practical: hundreds of candidates per invocation, each costing
//! milliseconds.
//!
//! ## Strategy search
//!
//! [`Searcher`] goes beyond the uniform grid: seeded simulated
//! annealing over **non-uniform strategy trees**
//! ([`crate::strategy::NonUniformSpec`]), sharing the sweep's scoring
//! path and compile cache. See [`search`].

pub mod search;
pub mod sweep;

pub use search::{
    default_inits, ChainReport, Evaluation, SearchConfig, SearchPoint, SearchResult, Searcher,
};
pub use sweep::{
    candidate_grid, candidate_grid_with_schedules, dedupe_specs, score_tree, score_tree_delta,
    Scenario, SweepOutcome, SweepRunner, TreeScore,
};

#[cfg(not(feature = "pjrt"))]
use crate::estimator::features::Row;
#[cfg(not(feature = "pjrt"))]
use crate::Result;

/// Fixed batch size the kernel was lowered with (rows are padded to a
/// multiple of this). Keep in sync with `python/compile/aot.py`.
pub const KERNEL_BATCH: usize = 4096;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use super::KERNEL_BATCH;
    use crate::estimator::features::{Row, FEATURES};
    use crate::{Error, Result};

    /// A compiled cost-model executable on the PJRT CPU client.
    pub struct CostKernel {
        exe: xla::PjRtLoadedExecutable,
        #[allow(dead_code)]
        client: xla::PjRtClient,
    }

    impl CostKernel {
        /// Load and compile `artifacts/costmodel.hlo.txt`.
        pub fn load(path: &str) -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| Error::Runtime(format!("parse {path}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {path}: {e}")))?;
            Ok(CostKernel { exe, client })
        }

        /// Evaluate cost rows; returns one cost (ns) per input row.
        pub fn eval(&self, rows: &[Row]) -> Result<Vec<f32>> {
            let mut out = Vec::with_capacity(rows.len());
            for chunk in rows.chunks(KERNEL_BATCH) {
                let mut flat = vec![0f32; KERNEL_BATCH * FEATURES];
                for (i, row) in chunk.iter().enumerate() {
                    flat[i * FEATURES..(i + 1) * FEATURES].copy_from_slice(row);
                }
                // Padding rows are all-zero: is_comm=0, flops=0, bytes=0,
                // eff=0 → cost = launch 0 + max(0,0) = 0; harmless.
                let lit = xla::Literal::vec1(&flat)
                    .reshape(&[KERNEL_BATCH as i64, FEATURES as i64])
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
                let result = self
                    .exe
                    .execute::<xla::Literal>(&[lit])
                    .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
                // aot.py lowers with return_tuple=True → 1-tuple.
                let tup = lit
                    .to_tuple1()
                    .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
                let vals = tup
                    .to_vec::<f32>()
                    .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
                out.extend_from_slice(&vals[..chunk.len()]);
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::CostKernel;

/// Stub cost kernel used when the crate is built without the `pjrt`
/// feature (the default, offline-friendly configuration).
///
/// `load` always fails with a descriptive [`crate::Error::Runtime`], so
/// `OpEstimator::best_available` falls back to the analytical mirror.
#[cfg(not(feature = "pjrt"))]
pub struct CostKernel {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl CostKernel {
    /// Always fails: the PJRT backend is compiled out.
    pub fn load(path: &str) -> Result<Self> {
        Err(crate::Error::Runtime(format!(
            "cannot load {path}: built without the 'pjrt' feature"
        )))
    }

    /// Unreachable in practice ([`CostKernel::load`] never succeeds
    /// without the `pjrt` feature).
    pub fn eval(&self, _rows: &[Row]) -> Result<Vec<f32>> {
        Err(crate::Error::Runtime(
            "built without the 'pjrt' feature".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_kernel_fails_cleanly() {
        let err = super::CostKernel::load("artifacts/costmodel.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    /// Full PJRT round-trip — requires `make artifacts` to have run.
    /// Validates the kernel against the Rust analytical mirror on real
    /// feature rows; this is the cross-layer correctness gate.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_kernel_matches_analytical_mirror() {
        use super::*;
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/costmodel.hlo.txt");
        if !std::path::Path::new(path).exists() {
            eprintln!("skipping: {path} missing (run `make artifacts`)");
            return;
        }
        let kernel = CostKernel::load(path).expect("load kernel");
        // Build rows straight from a compiled model.
        use crate::cluster::{Cluster, Preset};
        use crate::estimator::OpEstimator;
        use crate::models::ModelKind;
        use crate::strategy::{build_strategy, StrategySpec};
        let g = ModelKind::Gpt2.build(8);
        let tree = build_strategy(&g, StrategySpec::hybrid(2, 2, 1, 1)).unwrap();
        let c = Cluster::preset(Preset::HC2, 1);
        let eg = crate::compiler::compile(&g, &tree, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        let rows = est.feature_matrix(&eg);
        let expect: Vec<f32> = rows.iter().map(crate::estimator::cost_ns).collect();
        let got = kernel.eval(&rows).expect("eval");
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            let denom = e.abs().max(1.0);
            assert!(
                (g - e).abs() / denom < 1e-4,
                "row {i}: kernel {g} vs mirror {e}"
            );
        }
    }
}
