//! PJRT runtime: loads the AOT-compiled cost kernel and executes it from
//! the Rust hot path.
//!
//! The artifact is **HLO text** (not a serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the pinned
//! `xla_extension` 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md` and
//! `python/compile/aot.py`).
//!
//! Python never runs at simulation time: `make artifacts` lowers the
//! JAX/Pallas cost model once; this module compiles the text with the
//! PJRT CPU client at startup and then executes batches of feature rows
//! with no Python involvement.

use crate::estimator::features::{Row, FEATURES};
use crate::{Error, Result};

/// Fixed batch size the kernel was lowered with (rows are padded to a
/// multiple of this). Keep in sync with `python/compile/aot.py`.
pub const KERNEL_BATCH: usize = 4096;

/// A compiled cost-model executable on the PJRT CPU client.
pub struct CostKernel {
    exe: xla::PjRtLoadedExecutable,
    #[allow(dead_code)]
    client: xla::PjRtClient,
}

impl CostKernel {
    /// Load and compile `artifacts/costmodel.hlo.txt`.
    pub fn load(path: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {path}: {e}")))?;
        Ok(CostKernel { exe, client })
    }

    /// Evaluate cost rows; returns one cost (ns) per input row.
    pub fn eval(&self, rows: &[Row]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(KERNEL_BATCH) {
            let mut flat = vec![0f32; KERNEL_BATCH * FEATURES];
            for (i, row) in chunk.iter().enumerate() {
                flat[i * FEATURES..(i + 1) * FEATURES].copy_from_slice(row);
            }
            // Padding rows are all-zero: is_comm=0, flops=0, bytes=0,
            // eff=0 → cost = launch 0 + max(0,0) = 0; harmless.
            let lit = xla::Literal::vec1(&flat)
                .reshape(&[KERNEL_BATCH as i64, FEATURES as i64])
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
            // aot.py lowers with return_tuple=True → 1-tuple.
            let tup = lit
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
            let vals = tup
                .to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            out.extend_from_slice(&vals[..chunk.len()]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full PJRT round-trip — requires `make artifacts` to have run.
    /// Validates the kernel against the Rust analytical mirror on real
    /// feature rows; this is the cross-layer correctness gate.
    #[test]
    fn pjrt_kernel_matches_analytical_mirror() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/costmodel.hlo.txt");
        if !std::path::Path::new(path).exists() {
            eprintln!("skipping: {path} missing (run `make artifacts`)");
            return;
        }
        let kernel = CostKernel::load(path).expect("load kernel");
        // Build rows straight from a compiled model.
        use crate::cluster::{Cluster, Preset};
        use crate::estimator::OpEstimator;
        use crate::models::ModelKind;
        use crate::strategy::{build_strategy, StrategySpec};
        let g = ModelKind::Gpt2.build(8);
        let tree = build_strategy(&g, StrategySpec::hybrid(2, 2, 1, 1)).unwrap();
        let c = Cluster::preset(Preset::HC2, 1);
        let eg = crate::compiler::compile(&g, &tree, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        let rows = est.feature_matrix(&eg);
        let expect: Vec<f32> = rows.iter().map(crate::estimator::cost_ns).collect();
        let got = kernel.eval(&rows).expect("eval");
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            let denom = e.abs().max(1.0);
            assert!(
                (g - e).abs() / denom < 1e-4,
                "row {i}: kernel {g} vs mirror {e}"
            );
        }
    }
}
