//! Parallel scenario sweeps: simulate many `(model, cluster, strategy)`
//! candidates in one invocation and rank them by predicted throughput.
//!
//! This is the paper's motivating use case (§I): a simulator that costs
//! milliseconds per strategy turns parallelization planning into a
//! search problem. The [`SweepRunner`] exploits that:
//!
//! - **deduplicated compilation work** — scenarios sharing a `(model,
//!   batch)` pair reuse one computation-graph build, and scenarios
//!   sharing a `(preset, nodes)` pair reuse one cluster topology;
//! - **thread-pool parallelism** — scenarios are drained from an atomic
//!   work index by `std::thread::scope` workers (the crate is std-only
//!   so it builds offline; the design is drop-in replaceable by a rayon
//!   `par_iter` if the dependency is ever vendored);
//! - **fault isolation** — a scenario whose strategy fails to build or
//!   compile is recorded as an error outcome instead of aborting the
//!   sweep, so exhaustive grids can include aggressive candidates.
//!
//! The per-scenario simulation itself uses the analytical cost backend:
//! it is `Sync`, allocation-light, and bit-identical to the PJRT kernel
//! arithmetic (see [`crate::estimator`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::{Cluster, Preset};
use crate::collective::CollAlgo;
use crate::compiler::{EmitRecord, TemplateCache};
use crate::executor::{calibrate, Htae, HtaeConfig, SimReport};
use crate::graph::Graph;
use crate::models::ModelSpec;
use crate::strategy::{build_strategy, PipelineSchedule, StrategySpec, StrategyTree};

/// One sweep candidate: a model at a batch size, a cluster, a strategy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Model under test.
    pub model: ModelSpec,
    /// Global batch size.
    pub batch: usize,
    /// Hardware preset.
    pub preset: Preset,
    /// Nodes of the preset to instantiate.
    pub nodes: usize,
    /// Parallelization strategy.
    pub spec: StrategySpec,
}

impl Scenario {
    /// Human-readable scenario label.
    pub fn label(&self) -> String {
        format!(
            "{} b={} {}x{} {}",
            self.model.name(),
            self.batch,
            self.preset.name(),
            self.nodes,
            self.spec.label()
        )
    }
}

/// Result of simulating one [`Scenario`].
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// The scenario simulated.
    pub scenario: Scenario,
    /// The HTAE report, or a description of why the scenario failed
    /// (invalid strategy, compile error, simulation error).
    pub report: Result<SimReport, String>,
    /// Infeasible: the simulated peak memory exceeded the preset's
    /// device capacity. The candidate still carries its full report
    /// (step time, throughput, peaks) but [`SweepRunner::rank`] sorts it
    /// below every feasible candidate.
    pub oom: bool,
    /// Wall-clock seconds spent compiling the execution graph.
    pub compile_s: f64,
    /// Wall-clock seconds spent estimating + simulating.
    pub sim_s: f64,
    /// Device-equivalence classes folded (0 unless the sweep ran with
    /// symmetry folding and the candidate folded).
    pub fold_classes: usize,
    /// Devices whose task streams were folded away.
    pub fold_devices_folded: usize,
    /// Folding was requested but fell back to the unfolded graph.
    pub fold_fallback: bool,
}

impl SweepOutcome {
    /// Predicted throughput, if the scenario simulated without error or
    /// OOM.
    pub fn throughput(&self) -> Option<f64> {
        match &self.report {
            Ok(r) if !r.oom => Some(r.throughput),
            _ => None,
        }
    }

    /// One-line summary for logs and examples.
    pub fn describe(&self) -> String {
        match &self.report {
            Ok(r) if r.oom => format!("{}: OOM (infeasible)", self.scenario.label()),
            Ok(r) => format!(
                "{}: {:.1} samples/s ({:.2} ms/step)",
                self.scenario.label(),
                r.throughput,
                r.step_ms
            ),
            Err(e) => format!("{}: failed ({e})", self.scenario.label()),
        }
    }
}

/// Parallel sweep executor. See the module docs for the design.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
    plain: bool,
    coll_algo: CollAlgo,
    compile_cache: bool,
    fold: bool,
    nics: Option<usize>,
    oversub: Option<f64>,
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::new()
    }
}

impl SweepRunner {
    /// Runner sized to the machine (`available_parallelism`).
    pub fn new() -> Self {
        SweepRunner {
            threads: 0,
            plain: false,
            coll_algo: CollAlgo::Auto,
            compile_cache: true,
            fold: false,
            nics: None,
            oversub: None,
        }
    }

    /// Override the preset fabric for every scenario's cluster:
    /// `nics` NICs per node and/or an `oversub` fat-tree
    /// oversubscription ratio. Values must already be valid for the
    /// swept presets (the CLI validates them up front through
    /// [`Cluster::from_spec`]); invalid overrides panic here rather
    /// than silently reverting to the preset fabric.
    pub fn fabric(mut self, nics: Option<usize>, oversub: Option<f64>) -> Self {
        self.nics = nics;
        self.oversub = oversub;
        self
    }

    /// Enable symmetry folding (default off): each candidate compiles
    /// with device-equivalence folding, simulating one representative
    /// replica slice when the verification passes. Results are
    /// bit-identical either way — a candidate that cannot be proven
    /// symmetric falls back to the unfolded graph
    /// ([`SweepOutcome::fold_fallback`]).
    pub fn fold(mut self, on: bool) -> Self {
        self.fold = on;
        self
    }

    /// Override the worker-thread count (0 = auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Disable runtime-behavior modeling (HTAE "Plain" ablation) for
    /// every scenario.
    pub fn plain(mut self, on: bool) -> Self {
        self.plain = on;
        self
    }

    /// Collective lowering algorithm for every scenario (default
    /// [`CollAlgo::Auto`]; [`CollAlgo::Monolithic`] is the ablation).
    pub fn coll_algo(mut self, algo: CollAlgo) -> Self {
        self.coll_algo = algo;
        self
    }

    /// Toggle the cross-candidate compile cache (default on):
    /// candidates that share a model graph and a structurally identical
    /// resolved strategy — e.g. the same `dp×mp×pp(micro)` point swept
    /// under several pipeline schedules — compile the execution-graph
    /// template once and reuse it (see
    /// [`crate::compiler::TemplateCache`]). Sweep results are
    /// bit-identical with the cache off; this knob exists for A/B
    /// benchmarking and the pinning tests.
    pub fn compile_cache(mut self, on: bool) -> Self {
        self.compile_cache = on;
        self
    }

    /// Effective worker count for a sweep of `n_scenarios`.
    pub fn effective_threads(&self, n_scenarios: usize) -> usize {
        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let t = if self.threads > 0 { self.threads } else { auto };
        t.clamp(1, n_scenarios.max(1))
    }

    /// Simulate every scenario, in parallel, returning outcomes in input
    /// order. Shared model graphs and cluster topologies are built once.
    pub fn run(&self, scenarios: &[Scenario]) -> Vec<SweepOutcome> {
        let own = self.compile_cache.then(TemplateCache::new);
        self.run_with_cache(scenarios, own.as_ref())
    }

    /// [`Self::run`] against a caller-owned [`TemplateCache`] — the
    /// session layer passes its long-lived cache here so grid candidates
    /// share templates with earlier simulate/search requests. Templates
    /// are keyed by [`ModelSpec::graph_key`] (a stable
    /// `(model, batch)` identity) plus the resolved strategy's
    /// structural hash, so cross-request sharing is sound. `None`
    /// disables template caching entirely; outcomes are bit-identical
    /// either way (pinned below).
    pub fn run_with_cache(
        &self,
        scenarios: &[Scenario],
        cache: Option<&TemplateCache>,
    ) -> Vec<SweepOutcome> {
        if scenarios.is_empty() {
            return Vec::new();
        }

        // Dedupe the shared compilation work up front: one graph build
        // per model identity ([`ModelSpec::graph_key`] mixes the batch
        // in), one topology per (preset, nodes). A model that fails to
        // build (e.g. a bad external file) error-isolates every scenario
        // referencing it instead of aborting the sweep.
        let mut graph_keys: Vec<u64> = Vec::new();
        let mut graphs: Vec<std::result::Result<Graph, String>> = Vec::new();
        let mut cluster_keys: Vec<(Preset, usize)> = Vec::new();
        let mut clusters: Vec<Cluster> = Vec::new();
        let mut graph_of = Vec::with_capacity(scenarios.len());
        let mut cluster_of = Vec::with_capacity(scenarios.len());
        for sc in scenarios {
            let gk = sc.model.graph_key(sc.batch);
            let gi = match graph_keys.iter().position(|&k| k == gk) {
                Some(i) => i,
                None => {
                    graph_keys.push(gk);
                    graphs.push(sc.model.build(sc.batch).map_err(|e| e.to_string()));
                    graphs.len() - 1
                }
            };
            graph_of.push(gi);
            let ck = (sc.preset, sc.nodes);
            let ci = match cluster_keys.iter().position(|&k| k == ck) {
                Some(i) => i,
                None => {
                    cluster_keys.push(ck);
                    let cluster = if self.nics.is_some() || self.oversub.is_some() {
                        let mut spec = crate::cluster::presets::spec(sc.preset, sc.nodes);
                        if let Some(k) = self.nics {
                            spec.nics_per_node = k;
                        }
                        if let Some(r) = self.oversub {
                            spec.oversubscription = r;
                        }
                        Cluster::from_spec(&spec)
                            .expect("fabric overrides must be valid for the swept preset")
                    } else {
                        Cluster::preset(sc.preset, sc.nodes)
                    };
                    clusters.push(cluster);
                    clusters.len() - 1
                }
            };
            cluster_of.push(ci);
        }
        // γ is per-cluster; compute it once, outside the workers.
        let gammas: Vec<f64> = clusters.iter().map(calibrate::default_gamma).collect();
        // Cross-candidate compile cache: candidates differing only in
        // pipeline schedule (or in simulation knobs) share one compiled
        // template, keyed by the stable model graph identity + the
        // resolved strategy's structural hash. The stable key (not the
        // dedup index) keeps a shared session cache sound across
        // invocations with different scenario sets.
        let threads = self.effective_threads(scenarios.len());
        let next = AtomicUsize::new(0);
        let results: Vec<Mutex<Option<SweepOutcome>>> =
            scenarios.iter().map(|_| Mutex::new(None)).collect();
        let plain = self.plain;

        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    let sc = &scenarios[i];
                    let out = match &graphs[graph_of[i]] {
                        Ok(graph) => run_one(
                            sc,
                            graph,
                            &clusters[cluster_of[i]],
                            gammas[cluster_of[i]],
                            plain,
                            self.coll_algo,
                            cache.map(|c| (c, graph_keys[graph_of[i]])),
                            self.fold,
                        ),
                        Err(e) => SweepOutcome {
                            scenario: sc.clone(),
                            report: Err(e.clone()),
                            oom: false,
                            compile_s: 0.0,
                            sim_s: 0.0,
                            fold_classes: 0,
                            fold_devices_folded: 0,
                            fold_fallback: false,
                        },
                    };
                    *results[i].lock().unwrap() = Some(out);
                });
            }
        });

        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    }

    /// Rank outcomes: feasible candidates (no error, no OOM) first, best
    /// predicted throughput to worst; **infeasible (OOM) candidates sort
    /// below every feasible one**, themselves by throughput, so callers
    /// printing the top-k never recommend a strategy that cannot fit.
    /// Errored scenarios are excluded.
    ///
    /// Ties break on the scenario label (ascending), so ranked tables
    /// and `--json` artifacts are byte-stable across runs — equal
    /// throughputs are common (e.g. schedule variants of a
    /// compute-bound candidate) and an input-order tie-break would leak
    /// grid-enumeration changes into CI diffs.
    pub fn rank(outcomes: &[SweepOutcome]) -> Vec<&SweepOutcome> {
        // Sort keys (throughput, label) are precomputed once — labels
        // only break ties, and allocating them per comparison inside
        // sort_by would cost O(N log N) formatted Strings.
        fn sorted(mut keyed: Vec<(f64, String, &SweepOutcome)>) -> Vec<&SweepOutcome> {
            keyed.sort_by(|a, b| b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
            keyed.into_iter().map(|(_, _, o)| o).collect()
        }
        let viable = sorted(
            outcomes
                .iter()
                .filter_map(|o| o.throughput().map(|t| (t, o.scenario.label(), o)))
                .collect(),
        );
        // `oom && report.is_ok()`: run_one keeps the flag consistent
        // with the report, but the fields are pub — never panic on a
        // hand-built outcome.
        let infeasible = sorted(
            outcomes
                .iter()
                .filter(|o| o.oom && o.report.is_ok())
                .map(|o| {
                    (
                        o.report.as_ref().unwrap().throughput,
                        o.scenario.label(),
                        o,
                    )
                })
                .collect(),
        );
        let mut out = viable;
        out.extend(infeasible);
        out
    }
}

/// Result of scoring one built strategy tree — the shared inner loop of
/// the grid sweep and the simulated-annealing searcher
/// ([`crate::runtime::search`]).
#[derive(Debug, Clone)]
pub struct TreeScore {
    /// The HTAE report, or why compilation/simulation failed.
    pub report: Result<SimReport, String>,
    /// Simulated peak memory exceeded device capacity.
    pub oom: bool,
    /// Wall-clock seconds compiling (0 when the template cache hit and
    /// instantiation dominated).
    pub compile_s: f64,
    /// Wall-clock seconds estimating + simulating.
    pub sim_s: f64,
    /// Device-equivalence classes folded (0 when folding was off, fell
    /// back, or nothing was foldable).
    pub fold_classes: usize,
    /// Devices whose task streams were folded away.
    pub fold_devices_folded: usize,
    /// Folding was requested but a symmetry check failed.
    pub fold_fallback: bool,
    /// Seconds in the fold pass.
    pub fold_s: f64,
}

impl TreeScore {
    /// Predicted throughput if the tree simulated without error or OOM.
    pub fn throughput(&self) -> Option<f64> {
        match &self.report {
            Ok(r) if !r.oom => Some(r.throughput),
            _ => None,
        }
    }
}

/// Compile a built strategy tree and simulate one training step: the
/// scoring path every search/sweep candidate goes through, so the
/// sweep's ranked throughputs and the searcher's chain energies are
/// bit-comparable. `cache` is the cross-candidate [`TemplateCache`]
/// (keyed by the caller's graph id) — candidates that differ only in
/// pipeline schedule or simulation knobs recompile near-free.
pub fn score_tree(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    tree: &StrategyTree,
    plain: bool,
    coll_algo: CollAlgo,
    cache: Option<(&TemplateCache, u64)>,
) -> TreeScore {
    score_tree_delta(graph, cluster, gamma, tree, plain, coll_algo, cache, None, false).0
}

/// [`score_tree`] with symmetry folding selectable (see
/// [`crate::compiler::compile_with_opts`]).
#[allow(clippy::too_many_arguments)]
pub fn score_tree_opts(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    tree: &StrategyTree,
    plain: bool,
    coll_algo: CollAlgo,
    cache: Option<(&TemplateCache, u64)>,
    fold: bool,
) -> TreeScore {
    score_tree_delta_opts(
        graph, cluster, gamma, tree, plain, coll_algo, cache, None, false, fold,
    )
    .0
}

/// [`score_tree`] extended with the **delta re-compilation** hooks the
/// annealing searcher threads along each chain: `parent` is the
/// previously scored candidate's [`EmitRecord`] (template emission
/// resumes from its deepest valid stage checkpoint), `want_record`
/// requests a record for this candidate so the *next* neighbor can
/// resume from it. Scoring output is bit-identical to [`score_tree`];
/// only compile work differs.
#[allow(clippy::too_many_arguments)]
pub fn score_tree_delta(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    tree: &StrategyTree,
    plain: bool,
    coll_algo: CollAlgo,
    cache: Option<(&TemplateCache, u64)>,
    parent: Option<&EmitRecord>,
    want_record: bool,
) -> (TreeScore, Option<EmitRecord>) {
    score_tree_delta_opts(
        graph,
        cluster,
        gamma,
        tree,
        plain,
        coll_algo,
        cache,
        parent,
        want_record,
        false,
    )
}

/// [`score_tree_delta`] with symmetry folding selectable. The fold
/// statistics land in the returned [`TreeScore`].
#[allow(clippy::too_many_arguments)]
pub fn score_tree_delta_opts(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    tree: &StrategyTree,
    plain: bool,
    coll_algo: CollAlgo,
    cache: Option<(&TemplateCache, u64)>,
    parent: Option<&EmitRecord>,
    want_record: bool,
    fold: bool,
) -> (TreeScore, Option<EmitRecord>) {
    let t0 = Instant::now();
    let (eg, stats, record) = match crate::compiler::compile_delta_opts(
        graph,
        tree,
        cluster,
        cache,
        parent,
        want_record,
        fold,
    ) {
        Ok(ok) => ok,
        Err(e) => {
            return (
                TreeScore {
                    report: Err(e.to_string()),
                    oom: false,
                    compile_s: t0.elapsed().as_secs_f64(),
                    sim_s: 0.0,
                    fold_classes: 0,
                    fold_devices_folded: 0,
                    fold_fallback: false,
                    fold_s: 0.0,
                },
                None,
            )
        }
    };
    let compile_s = t0.elapsed().as_secs_f64();
    let est = crate::estimator::OpEstimator::analytical(cluster);
    let mut config = if plain {
        HtaeConfig::plain()
    } else {
        HtaeConfig {
            gamma,
            ..HtaeConfig::default()
        }
    };
    config.coll_algo = coll_algo;
    let t1 = Instant::now();
    let report = Htae::with_config(cluster, &est, config)
        .simulate(&eg)
        .map_err(|e| e.to_string());
    let oom = report.as_ref().map(|r| r.oom).unwrap_or(false);
    (
        TreeScore {
            report,
            oom,
            compile_s,
            sim_s: t1.elapsed().as_secs_f64(),
            fold_classes: stats.fold_classes,
            fold_devices_folded: stats.fold_devices_folded,
            fold_fallback: stats.fold_fallback,
            fold_s: stats.fold_s,
        },
        record,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    sc: &Scenario,
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    plain: bool,
    coll_algo: CollAlgo,
    cache: Option<(&TemplateCache, u64)>,
    fold: bool,
) -> SweepOutcome {
    let tree = match build_strategy(graph, sc.spec) {
        Ok(t) => t,
        Err(e) => {
            return SweepOutcome {
                scenario: sc.clone(),
                report: Err(e.to_string()),
                oom: false,
                compile_s: 0.0,
                sim_s: 0.0,
                fold_classes: 0,
                fold_devices_folded: 0,
                fold_fallback: false,
            }
        }
    };
    let s = score_tree_opts(graph, cluster, gamma, &tree, plain, coll_algo, cache, fold);
    SweepOutcome {
        scenario: sc.clone(),
        report: s.report,
        oom: s.oom,
        compile_s: s.compile_s,
        sim_s: s.sim_s,
        fold_classes: s.fold_classes,
        fold_devices_folded: s.fold_devices_folded,
        fold_fallback: s.fold_fallback,
    }
}

/// Exhaustive strategy grid for `n_devices` GPUs at global batch
/// `batch`: every `dp × mp × pp` factorization (pp ∈ {1, 2, 4, 8}),
/// micro-batch counts compatible with the batch, and the ZeRO /
/// recomputation toggles (recompute only without pipelining, matching
/// the compiler's supported space).
///
/// The grid deliberately includes aggressive candidates (e.g. high `mp`
/// on models whose head counts don't divide) — [`SweepRunner`] records
/// those as error outcomes rather than failing the sweep.
///
/// Every pipelined candidate uses the default 1F1B schedule; use
/// [`candidate_grid_with_schedules`] to also rank GPipe fill-drain and
/// interleaved-1F1B variants.
pub fn candidate_grid(n_devices: usize, batch: usize) -> Vec<StrategySpec> {
    let mut out = Vec::new();
    for pp in [1usize, 2, 4, 8] {
        if n_devices % pp != 0 {
            continue;
        }
        let rest = n_devices / pp;
        for dp in 1..=rest {
            if rest % dp != 0 || batch % dp != 0 {
                continue;
            }
            let mp = rest / dp;
            if !mp.is_power_of_two() {
                continue;
            }
            let micros: &[usize] = if pp > 1 { &[2, 4, 8] } else { &[1, 2, 4, 8] };
            for &micro in micros {
                if batch % (dp * micro) != 0 {
                    continue;
                }
                let base = StrategySpec::hybrid(dp, mp, pp, micro);
                out.push(base);
                out.push(base.with_zero());
                if pp == 1 {
                    out.push(base.with_recompute());
                    out.push(base.with_zero().with_recompute());
                }
            }
        }
    }
    out
}

/// [`candidate_grid`] expanded across pipeline schedules: every
/// pipelined (`pp > 1`) candidate is repeated once per schedule in
/// `schedules`; single-stage candidates are schedule-independent and
/// appear once. Duplicate specs (e.g. a schedule listed twice) are
/// dropped, so `proteus sweep --schedules all` ranks GPipe / 1F1B /
/// interleaved head-to-head in one invocation.
///
/// `max_ep` is the workload's expert count (1 for dense models — pass
/// [`crate::graph::Graph::expert_capacity`]`.unwrap_or(1)`): for each
/// expert-parallel degree `ep > 1` that divides both the expert count
/// and the device budget, the grid is extended with the full
/// `dp × mp × pp` factorization of the remaining `n_devices / ep`
/// budget at that `ep`. With `max_ep == 1` the output is exactly the
/// historical dense grid, entry for entry.
pub fn candidate_grid_with_schedules(
    n_devices: usize,
    batch: usize,
    schedules: &[PipelineSchedule],
    max_ep: usize,
) -> Vec<StrategySpec> {
    fn expand(bases: Vec<StrategySpec>, schedules: &[PipelineSchedule], out: &mut Vec<StrategySpec>) {
        for base in bases {
            if base.pp == 1 {
                if !out.contains(&base) {
                    out.push(base);
                }
                continue;
            }
            for &s in schedules {
                let sp = base.with_schedule(s);
                if !out.contains(&sp) {
                    out.push(sp);
                }
            }
        }
    }
    let mut out: Vec<StrategySpec> = Vec::new();
    expand(candidate_grid(n_devices, batch), schedules, &mut out);
    // Expert-parallel extension. Aggressive candidates (e.g. an ep×mp
    // combination the expert shapes cannot absorb) are included on
    // purpose — the sweep's error isolation reports them.
    for ep in 2..=max_ep.min(n_devices) {
        if max_ep % ep != 0 || n_devices % ep != 0 {
            continue;
        }
        let bases: Vec<StrategySpec> = candidate_grid(n_devices / ep, batch)
            .into_iter()
            .map(|s| s.with_moe(ep))
            .collect();
        expand(bases, schedules, &mut out);
    }
    out
}

/// Drop grid candidates that resolve to the **same strategy** as an
/// earlier one. Distinct `StrategySpec` tuples can commute into
/// identical resolved strategies — e.g. a ZeRO toggle on a spec whose
/// parameters are already fully sharded (nothing left to refine), or an
/// `mp` degree no layer dimension can absorb — and simulating both
/// wastes sweep budget and pads ranked tables with tied duplicates.
///
/// Equivalence is decided on the resolved strategy's structural hash
/// pair plus the schedule knobs the hash deliberately excludes
/// (pipeline schedule, `max_ongoing`). Specs that fail to build or
/// resolve are kept verbatim — the sweep's error isolation reports
/// them.
pub fn dedupe_specs(graph: &Graph, specs: Vec<StrategySpec>) -> Vec<StrategySpec> {
    let mut seen: std::collections::HashSet<(u64, u64, PipelineSchedule, usize)> =
        std::collections::HashSet::new();
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let key = build_strategy(graph, spec)
            .ok()
            .and_then(|tree| crate::strategy::resolve(graph, &tree).ok())
            .map(|r| {
                (
                    r.structural_hash(0x5EED_CAFE),
                    r.structural_hash(0x0DDB_A11),
                    spec.schedule,
                    spec.max_ongoing,
                )
            });
        match key {
            Some(k) => {
                if seen.insert(k) {
                    out.push(spec);
                }
            }
            None => out.push(spec),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelKind;

    #[test]
    fn grid_is_large_and_valid() {
        let specs = candidate_grid(16, 64);
        assert!(specs.len() >= 100, "grid too small: {}", specs.len());
        for s in &specs {
            assert_eq!(s.dp * s.mp * s.pp, 16, "{}", s.label());
            assert_eq!(64 % (s.dp * s.n_micro_batch), 0, "{}", s.label());
            assert!(!(s.recompute && s.pp > 1), "{}", s.label());
        }
    }

    #[test]
    fn grid_with_schedules_expands_pipelined_candidates_only() {
        let base = candidate_grid(8, 32);
        let all = candidate_grid_with_schedules(8, 32, &PipelineSchedule::all(), 1);
        let pipelined = base.iter().filter(|s| s.pp > 1).count();
        assert!(pipelined > 0, "grid must contain pipelined candidates");
        // Each pipelined candidate appears once per schedule; the rest
        // are unchanged.
        assert_eq!(all.len(), base.len() + 2 * pipelined);
        for s in &all {
            if s.pp == 1 {
                assert_eq!(s.schedule, PipelineSchedule::OneFOneB, "{}", s.label());
            }
        }
        // A single-schedule expansion is the plain grid.
        let one = candidate_grid_with_schedules(8, 32, &[PipelineSchedule::OneFOneB], 1);
        assert_eq!(one, base);
        // No duplicates even with a repeated schedule list.
        let dup = candidate_grid_with_schedules(
            8,
            32,
            &[PipelineSchedule::OneFOneB, PipelineSchedule::OneFOneB],
            1,
        );
        assert_eq!(dup, base);
    }

    /// Tentpole pin: the expert-parallel grid extension is additive —
    /// the dense prefix is byte-for-byte the historical grid, and every
    /// appended candidate carries an `ep` that divides both the expert
    /// count and the device budget, with the residual `dp·mp·pp`
    /// factorization spanning `n_devices / ep`.
    #[test]
    fn grid_extends_with_expert_parallel_candidates() {
        let sched = [PipelineSchedule::OneFOneB];
        let dense = candidate_grid_with_schedules(8, 32, &sched, 1);
        let moe = candidate_grid_with_schedules(8, 32, &sched, 4);
        assert_eq!(&moe[..dense.len()], &dense[..], "dense prefix must be unchanged");
        let appended: Vec<_> = moe[dense.len()..].to_vec();
        assert!(!appended.is_empty(), "ep=2 and ep=4 candidates must appear");
        for s in &appended {
            assert!(s.moe == 2 || s.moe == 4, "{}", s.label());
            assert_eq!(s.dp * s.mp * s.pp * s.moe, 8, "{}", s.label());
            assert_eq!(s.n_devices(), 8, "{}", s.label());
        }
        assert!(appended.iter().any(|s| s.moe == 2));
        assert!(appended.iter().any(|s| s.moe == 4));
        // An expert count with no divisor ≤ the device budget adds
        // nothing; ep degrees that don't divide the expert count are
        // skipped (max_ep 3 on an 8-device budget → dense only).
        assert_eq!(candidate_grid_with_schedules(8, 32, &sched, 3), dense);
    }

    #[test]
    fn grid_has_no_duplicates() {
        let specs = candidate_grid(8, 32);
        for (i, a) in specs.iter().enumerate() {
            for b in &specs[i + 1..] {
                assert_ne!(a, b, "duplicate spec {}", a.label());
            }
        }
    }

    #[test]
    fn sweep_runs_ranks_and_dedupes() {
        // Small but real sweep: 2 devices, a handful of strategies.
        let scenarios: Vec<Scenario> = candidate_grid(2, 16)
            .into_iter()
            .map(|spec| Scenario {
                model: ModelSpec::preset(ModelKind::Vgg19),
                batch: 16,
                preset: Preset::HC1,
                nodes: 1,
                spec,
            })
            .collect();
        assert!(scenarios.len() >= 4);
        let outcomes = SweepRunner::new().with_threads(2).run(&scenarios);
        assert_eq!(outcomes.len(), scenarios.len());
        // Outcomes come back in input order.
        for (o, sc) in outcomes.iter().zip(&scenarios) {
            assert_eq!(o.scenario, *sc);
        }
        let ranked = SweepRunner::rank(&outcomes);
        assert!(!ranked.is_empty(), "at least plain DP must simulate");
        // Feasible candidates first (throughput-sorted), then any OOM
        // ones (never interleaved).
        let n_feasible = ranked.iter().take_while(|o| !o.oom).count();
        for w in ranked[..n_feasible].windows(2) {
            assert!(w[0].throughput().unwrap() >= w[1].throughput().unwrap());
        }
        assert!(
            ranked[n_feasible..].iter().all(|o| o.oom),
            "infeasible candidates must all sort below feasible ones"
        );
    }

    /// Satellite pin: an OOM candidate is marked infeasible and ranked
    /// below every feasible candidate even when its raw throughput would
    /// place it first.
    #[test]
    fn oom_candidates_rank_below_feasible() {
        let mk = |oom: bool, throughput: f64| SweepOutcome {
            scenario: Scenario {
                model: ModelSpec::preset(ModelKind::Vgg19),
                batch: 16,
                preset: Preset::HC1,
                nodes: 1,
                spec: StrategySpec::data_parallel(2),
            },
            report: Ok(SimReport {
                step_ms: 1.0,
                throughput,
                peak_mem: vec![0],
                peak_act: vec![0],
                oom,
                overlapped_ops: 0,
                shared_ops: 0,
                n_tasks: 1,
                timeline: Vec::new(),
                comm_phases: Vec::new(),
                engine: None,
            }),
            oom,
            compile_s: 0.0,
            sim_s: 0.0,
            fold_classes: 0,
            fold_devices_folded: 0,
            fold_fallback: false,
        };
        let outcomes = vec![mk(true, 1000.0), mk(false, 10.0), mk(false, 50.0)];
        let ranked = SweepRunner::rank(&outcomes);
        assert_eq!(ranked.len(), 3);
        assert!(!ranked[0].oom && !ranked[1].oom);
        assert_eq!(ranked[0].report.as_ref().unwrap().throughput, 50.0);
        assert!(ranked[2].oom, "the fastest-but-OOM candidate sorts last");
        assert!(ranked[2].describe().contains("OOM"));
    }

    /// Satellite pin: equal throughputs rank by scenario label, so the
    /// ranked order is independent of input order and byte-stable
    /// across runs.
    #[test]
    fn rank_breaks_throughput_ties_by_label() {
        let mk = |spec: StrategySpec, throughput: f64| SweepOutcome {
            scenario: Scenario {
                model: ModelSpec::preset(ModelKind::Vgg19),
                batch: 16,
                preset: Preset::HC1,
                nodes: 1,
                spec,
            },
            report: Ok(SimReport {
                step_ms: 1.0,
                throughput,
                peak_mem: vec![0],
                peak_act: vec![0],
                oom: false,
                overlapped_ops: 0,
                shared_ops: 0,
                n_tasks: 1,
                timeline: Vec::new(),
                comm_phases: Vec::new(),
                engine: None,
            }),
            oom: false,
            compile_s: 0.0,
            sim_s: 0.0,
            fold_classes: 0,
            fold_devices_folded: 0,
            fold_fallback: false,
        };
        let a = mk(StrategySpec::hybrid(4, 2, 1, 1), 100.0);
        let b = mk(StrategySpec::hybrid(2, 4, 1, 1), 100.0);
        let c = mk(StrategySpec::hybrid(8, 1, 1, 1), 100.0);
        let fwd = vec![a.clone(), b.clone(), c.clone()];
        let rev = vec![c, b, a];
        let order = |os: &[SweepOutcome]| -> Vec<String> {
            SweepRunner::rank(os)
                .iter()
                .map(|o| o.scenario.label())
                .collect()
        };
        let (of, or) = (order(&fwd), order(&rev));
        assert_eq!(of, or, "tie order must not depend on input order");
        let mut sorted = of.clone();
        sorted.sort();
        assert_eq!(of, sorted, "ties break on ascending label");
    }

    /// Satellite pin: commuting factorizations that resolve to the same
    /// strategy (here: a ZeRO toggle with nothing left to shard) are
    /// simulated once; genuinely different candidates — including
    /// schedule-only variants, which the structural hash ignores — all
    /// survive.
    #[test]
    fn dedupe_drops_commuting_duplicates_only() {
        use crate::graph::{DType, GraphBuilder};
        let mut b = GraphBuilder::new("tiny", 16);
        let x = b.input("x", &[16, 64], DType::F32);
        let h = b.scoped("s0", |b| b.linear("fc", x, 64, 64));
        let h = b.scoped("s1", |b| b.linear("fc", h, 64, 64));
        let _ = b.loss("loss", h);
        let g = b.finish();

        // mp=2 fully shards both linears' params (ColSplit hint splits
        // weight and bias alike) → ZeRO has nothing to refine and the
        // toggle commutes away. Under dp=2 the params replicate, so the
        // ZeRO variant is a genuinely different strategy.
        let specs = vec![
            StrategySpec::hybrid(1, 2, 1, 1),
            StrategySpec::hybrid(1, 2, 1, 1).with_zero(),
            StrategySpec::data_parallel(2),
            StrategySpec::data_parallel(2).with_zero(),
            StrategySpec::hybrid(1, 1, 2, 4),
            StrategySpec::hybrid(1, 1, 2, 4).with_schedule(PipelineSchedule::GpipeFillDrain),
            // Invalid (batch 16 % 3 ≠ 0): kept for error isolation.
            StrategySpec::hybrid(3, 1, 1, 1),
        ];
        let deduped = dedupe_specs(&g, specs.clone());
        assert_eq!(deduped.len(), specs.len() - 1);
        assert!(deduped.contains(&StrategySpec::hybrid(1, 2, 1, 1)));
        assert!(!deduped.contains(&StrategySpec::hybrid(1, 2, 1, 1).with_zero()));
        assert!(deduped.contains(&StrategySpec::data_parallel(2).with_zero()));
        assert!(
            deduped.contains(&StrategySpec::hybrid(1, 1, 2, 4).with_schedule(
                PipelineSchedule::GpipeFillDrain
            )),
            "schedule-only variants must survive dedup"
        );
        assert!(deduped.contains(&StrategySpec::hybrid(3, 1, 1, 1)));
        // Idempotent.
        assert_eq!(dedupe_specs(&g, deduped.clone()), deduped);
    }

    /// Tentpole pin at the sweep level: candidates differing only in
    /// pipeline schedule share one compiled template, and the ranked
    /// results are bit-identical with the cache disabled.
    #[test]
    fn sweep_results_identical_with_and_without_compile_cache() {
        let specs = candidate_grid_with_schedules(2, 16, &PipelineSchedule::all(), 1);
        let scenarios: Vec<Scenario> = specs
            .into_iter()
            .map(|spec| Scenario {
                model: ModelSpec::preset(ModelKind::Vgg19),
                batch: 16,
                preset: Preset::HC1,
                nodes: 1,
                spec,
            })
            .collect();
        let cached = SweepRunner::new().with_threads(2).run(&scenarios);
        let uncached = SweepRunner::new()
            .with_threads(2)
            .compile_cache(false)
            .run(&scenarios);
        for (a, b) in cached.iter().zip(&uncached) {
            assert_eq!(a.scenario, b.scenario);
            match (&a.report, &b.report) {
                (Ok(ra), Ok(rb)) => {
                    assert_eq!(ra.step_ms, rb.step_ms, "{}", a.scenario.label());
                    assert_eq!(ra.peak_mem, rb.peak_mem, "{}", a.scenario.label());
                    assert_eq!(ra.n_tasks, rb.n_tasks, "{}", a.scenario.label());
                }
                (Err(ea), Err(eb)) => assert_eq!(ea, eb),
                _ => panic!("cache changed outcome kind for {}", a.scenario.label()),
            }
            assert_eq!(a.oom, b.oom);
        }
    }

    /// Tentpole pin at the sweep level: a folded sweep's reports
    /// bit-match the unfolded sweep's on every candidate — folding only
    /// changes how many tasks are materialized.
    #[test]
    fn sweep_results_identical_with_and_without_fold() {
        let scenarios: Vec<Scenario> = candidate_grid(4, 16)
            .into_iter()
            .map(|spec| Scenario {
                model: ModelSpec::preset(ModelKind::Vgg19),
                batch: 16,
                preset: Preset::HC1,
                nodes: 1,
                spec,
            })
            .collect();
        let folded = SweepRunner::new().with_threads(2).fold(true).run(&scenarios);
        let plain = SweepRunner::new().with_threads(2).run(&scenarios);
        let mut any_folded = false;
        for (a, b) in folded.iter().zip(&plain) {
            assert_eq!(a.scenario, b.scenario);
            match (&a.report, &b.report) {
                (Ok(ra), Ok(rb)) => {
                    assert_eq!(ra.step_ms, rb.step_ms, "{}", a.scenario.label());
                    assert_eq!(ra.peak_mem, rb.peak_mem, "{}", a.scenario.label());
                    assert_eq!(ra.oom, rb.oom, "{}", a.scenario.label());
                }
                (Err(ea), Err(eb)) => assert_eq!(ea, eb),
                _ => panic!("fold changed outcome kind for {}", a.scenario.label()),
            }
            any_folded |= a.fold_classes > 0;
            assert_eq!(b.fold_classes, 0, "fold off must report no classes");
        }
        assert!(any_folded, "at least the pure-DP candidates must fold");
    }

    #[test]
    fn sweep_matches_sequential_simulation() {
        // The parallel sweep must be a pure reordering of sequential
        // simulation: same reports, bit-identical step times.
        let scenarios: Vec<Scenario> = [
            StrategySpec::data_parallel(2),
            StrategySpec::data_parallel(4),
            StrategySpec::hybrid(2, 2, 1, 1),
        ]
        .into_iter()
        .map(|spec| Scenario {
            model: ModelSpec::preset(ModelKind::Vgg19),
            batch: 16,
            preset: Preset::HC1,
            nodes: 1,
            spec,
        })
        .collect();
        let par = SweepRunner::new().with_threads(3).run(&scenarios);
        let seq = SweepRunner::new().with_threads(1).run(&scenarios);
        for (a, b) in par.iter().zip(&seq) {
            let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
            assert_eq!(ra.step_ms, rb.step_ms, "{}", a.scenario.label());
            assert_eq!(ra.peak_mem, rb.peak_mem);
        }
    }

    #[test]
    fn invalid_strategies_are_isolated() {
        let scenarios = [Scenario {
            model: ModelSpec::preset(ModelKind::Vgg19),
            batch: 16,
            preset: Preset::HC1,
            nodes: 1,
            // dp=3 does not divide the batch evenly into device count 8.
            spec: StrategySpec::hybrid(3, 1, 1, 1),
        }];
        let outcomes = SweepRunner::new().run(&scenarios);
        assert_eq!(outcomes.len(), 1);
        // Either an error or a report — but never a panic/abort.
        let _ = outcomes[0].describe();
    }
}
