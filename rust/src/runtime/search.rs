//! Simulated-annealing / MCMC search over **non-uniform strategy
//! trees** (FlexFlow-style, paper §I's automated-parallelization use
//! case).
//!
//! The uniform `DP × MP × PP` grid ([`super::candidate_grid`]) scores a
//! few hundred expert-shaped points; the strategy tree can express far
//! more — per-stage degrees, moved stage boundaries, per-stage ZeRO.
//! [`Searcher`] walks that space with `K` independent Metropolis chains:
//!
//! - each chain starts from a seed point ([`SearchPoint`]), draws
//!   neighbors from the mutation-op library
//!   ([`crate::strategy::nonuniform`]), and accepts moves by the
//!   Metropolis rule under a geometrically cooling temperature;
//! - every candidate goes through the **same scoring path as the
//!   sweep** ([`super::score_tree`]): build → resolve/propagate →
//!   compile → HTAE-simulate, so chain energies and grid throughputs
//!   are bit-comparable;
//! - infeasible candidates (OOM per [`super::SweepOutcome`] semantics,
//!   or compile errors) are rejected moves, not crashes;
//! - chains share one [`TemplateCache`] keyed by the resolved
//!   strategy's structural hash, so schedule-only mutations recompile
//!   near-free;
//! - the budget is counted in **simulations**, split evenly across
//!   chains, which makes a seeded search bit-reproducible regardless of
//!   thread scheduling (each chain's walk depends only on its own seed;
//!   an optional wall-clock limit exists for interactive use and is the
//!   one knob that trades reproducibility for latency);
//! - **delta re-simulation** ([`SearchConfig::delta`]): each chain
//!   threads the current point's [`EmitRecord`] into the neighbor's
//!   compile ([`crate::compiler::compile_delta`]), so a mutation that
//!   leaves a leading stage prefix untouched re-emits only the touched
//!   suffix and splices the rest from the parent's checkpoints. This is
//!   a pure acceleration: accepted moves, chain energies, counters, and
//!   `--json` output are **bit-identical** with it on or off (pinned by
//!   `tests/differential_search.rs`);
//! - **bound-based pruning** ([`SearchConfig::prune`]): neighbors whose
//!   closed-form admissible lower bound
//!   ([`crate::compiler::htae_lower_bound_ms`]) already exceeds the
//!   chain's best feasible step time are rejected without simulating.
//!   Unlike delta, pruning *does* redirect the walk (pruned neighbors
//!   are never Metropolis-accepted), so it is a separate knob — the
//!   differential harness compares delta on/off at fixed prune state.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::Cluster;
use crate::collective::CollAlgo;
use crate::compiler::{htae_lower_bound_ms, EmitRecord, TemplateCache};
use crate::executor::calibrate;
use crate::graph::Graph;
use crate::runtime::sweep::score_tree_delta_opts;
use crate::strategy::nonuniform::{propose, NonUniformSpec};
use crate::strategy::{resolve, StrategySpec, StrategyTree};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Seed for the per-stage hash vectors the chains classify proposals
/// with (delta-hit vs full-compile). The classification runs on **every**
/// proposal regardless of [`SearchConfig::delta`], so the reported
/// counters — and the `--json` document — are identical between delta
/// and no-delta runs.
const CLASSIFY_SEED: u64 = 0x00DE_17A5;

/// One point of the search space: a non-uniform strategy spec plus the
/// collective-algorithm knob (which the paper's simulator exposes and a
/// strategy planner legitimately co-optimizes).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchPoint {
    /// The strategy.
    pub spec: NonUniformSpec,
    /// Collective lowering used when scoring this point.
    pub coll_algo: CollAlgo,
}

impl SearchPoint {
    /// Point with the default [`CollAlgo::Auto`] lowering.
    pub fn new(spec: NonUniformSpec) -> SearchPoint {
        SearchPoint {
            spec,
            coll_algo: CollAlgo::Auto,
        }
    }

    /// Seed point from a uniform grid candidate (see
    /// [`NonUniformSpec::from_uniform`]); scoring it reproduces the
    /// sweep's prediction for the same spec bit-for-bit.
    pub fn from_uniform(graph: &Graph, spec: StrategySpec) -> Result<SearchPoint> {
        Ok(SearchPoint::new(NonUniformSpec::from_uniform(graph, spec)?))
    }

    /// Display label: the spec label, plus the collective algorithm
    /// when it differs from the default.
    pub fn label(&self) -> String {
        let mut s = self.spec.label();
        if self.coll_algo != CollAlgo::Auto {
            s.push_str("+coll=");
            s.push_str(self.coll_algo.name());
        }
        s
    }
}

/// The scored outcome of one candidate evaluation.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// The point evaluated.
    pub point: SearchPoint,
    /// Cached [`SearchPoint::label`] of the point.
    pub label: String,
    /// Predicted step time (ms); `f64::INFINITY` on error.
    pub step_ms: f64,
    /// Predicted throughput (samples/s); 0 on error.
    pub throughput: f64,
    /// Max per-device predicted peak memory (bytes).
    pub peak_mem: u64,
    /// Peak memory exceeded device capacity.
    pub oom: bool,
    /// Build/compile/simulation failure, if any.
    pub error: Option<String>,
    /// Device-equivalence classes the fold pass kept (0 without
    /// [`SearchConfig::fold`]).
    pub fold_classes: usize,
    /// Devices elided by folding (0 without folding).
    pub fold_devices_folded: usize,
    /// Folding was requested but a symmetry check failed, so this
    /// candidate was scored on the unfolded graph.
    pub fold_fallback: bool,
}

impl Evaluation {
    /// True when the candidate simulated cleanly and fits in memory.
    pub fn feasible(&self) -> bool {
        self.error.is_none() && !self.oom
    }
}

/// Per-chain statistics of one search run.
#[derive(Debug, Clone)]
pub struct ChainReport {
    /// Chain index.
    pub chain: usize,
    /// The chain's derived RNG seed.
    pub seed: u64,
    /// Simulations this chain spent.
    pub evals: usize,
    /// Moves accepted by the Metropolis rule.
    pub accepted: usize,
    /// Candidates rejected for infeasibility (OOM or error).
    pub infeasible: usize,
    /// Evaluated proposals whose per-stage hashes agreed with the
    /// current point on ≥ 1 leading stage (the delta path re-emits at
    /// most a suffix for these). Counted by classification, so the
    /// value is identical whether or not delta is enabled.
    pub delta_hits: usize,
    /// Evaluated proposals with no reusable stage prefix (full template
    /// emission), including the chain's initial evaluation.
    pub full_compiles: usize,
    /// Proposals rejected by the admissible lower bound without
    /// spending a simulation.
    pub bound_prunes: usize,
    /// Best feasible evaluation the chain found.
    pub best: Option<Evaluation>,
}

/// Aggregate result of a [`Searcher::run`].
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best feasible evaluation across all chains (`None` when nothing
    /// feasible was found within budget).
    pub best: Option<Evaluation>,
    /// Per-chain reports, in chain order.
    pub chains: Vec<ChainReport>,
    /// Total simulations spent.
    pub evals: usize,
    /// Total delta-classified evaluations (see
    /// [`ChainReport::delta_hits`]).
    pub delta_hits: usize,
    /// Total full-template evaluations (see
    /// [`ChainReport::full_compiles`]).
    pub full_compiles: usize,
    /// Total bound-pruned proposals (see [`ChainReport::bound_prunes`]).
    pub bound_prunes: usize,
    /// Wall-clock seconds (informational; deliberately **not** part of
    /// the `--json` schema so seeded runs diff byte-identical).
    pub wall_s: f64,
    /// Template-cache hits this run contributed (a snapshot delta, so
    /// the number is the same whether the cache is run-local or a
    /// shared session cache; thread-interleaving dependent and also
    /// excluded from `--json`).
    pub cache_hits: usize,
    /// Template-cache misses this run contributed (snapshot delta).
    pub cache_misses: usize,
}

/// Search hyper-parameters. The defaults suit a few hundred simulations
/// on a 16–32 GPU scenario.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    /// Base RNG seed; chain `i` runs on `seed + i`.
    pub seed: u64,
    /// Total simulation budget across all chains.
    pub budget: usize,
    /// Independent annealing chains.
    pub chains: usize,
    /// Worker threads (0 = auto; capped at the chain count).
    pub threads: usize,
    /// Initial temperature, as a relative step-time fraction: a move
    /// that worsens step time by `t0` is accepted with probability
    /// `1/e` at the start of the schedule.
    pub t0: f64,
    /// Final temperature of the geometric cooling schedule.
    pub t1: f64,
    /// Score with runtime-behavior modeling disabled (ablation).
    pub plain: bool,
    /// Allow the collective-algorithm mutation (disable to pin
    /// `coll_algo` to the seed points' value).
    pub mutate_coll: bool,
    /// Share one [`TemplateCache`] across chains (bit-identical results
    /// either way; off only for A/B benchmarking).
    pub compile_cache: bool,
    /// Delta re-simulation: resume template emission from the current
    /// point's stage checkpoints. Bit-identical results either way —
    /// only compile work differs (`--no-delta` for A/B runs).
    pub delta: bool,
    /// Branch-and-bound pruning: reject neighbors whose admissible
    /// lower bound exceeds the chain's best feasible step time without
    /// simulating them. Redirects the walk (a pruned neighbor cannot be
    /// Metropolis-accepted), so seeded results are comparable only at
    /// fixed prune state.
    pub prune: bool,
    /// Optional wall-clock budget in seconds: chains stop proposing
    /// once it is exhausted. **Nondeterministic** — leave `None` for
    /// reproducible runs.
    pub wall_s: Option<f64>,
    /// Symmetry folding: compile every candidate with
    /// device-equivalence folding (see
    /// [`crate::compiler::compile_with_opts`]). Bit-identical scoring
    /// either way — candidates that cannot be proven symmetric fall
    /// back to the unfolded graph.
    pub fold: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            seed: 42,
            budget: 200,
            chains: 4,
            threads: 0,
            t0: 0.08,
            t1: 0.005,
            plain: false,
            mutate_coll: true,
            compile_cache: true,
            delta: true,
            prune: true,
            wall_s: None,
            fold: false,
        }
    }
}

/// The simulated-annealing strategy searcher. See the module docs for
/// the algorithm; construct with a [`SearchConfig`] and call
/// [`Searcher::run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct Searcher {
    config: SearchConfig,
}

impl Searcher {
    /// Searcher with the given hyper-parameters.
    pub fn new(config: SearchConfig) -> Searcher {
        Searcher { config }
    }

    /// The configuration this searcher runs with.
    pub fn config(&self) -> &SearchConfig {
        &self.config
    }

    /// Run the search: chain `i` anneals from `inits[i % inits.len()]`
    /// with its share of the simulation budget. Chains run in parallel
    /// on a thread pool but are individually deterministic, so the
    /// result depends only on `(graph, cluster, config, inits)`.
    pub fn run(
        &self,
        graph: &Graph,
        cluster: &Cluster,
        inits: &[SearchPoint],
    ) -> Result<SearchResult> {
        self.run_with_cache(graph, cluster, inits, None)
    }

    /// [`Self::run`] against a caller-owned [`TemplateCache`] paired
    /// with a stable graph key ([`crate::models::ModelSpec::graph_key`])
    /// — the session layer passes its long-lived cache here so chain
    /// evaluations share templates with earlier requests. With
    /// `external: None` the searcher owns a run-local cache (exactly
    /// [`Self::run`]); either way [`SearchConfig::compile_cache`] turns
    /// caching off entirely, and results are bit-identical in all three
    /// modes. The returned hit/miss counters are the *delta* this run
    /// contributed (snapshot-based), so a shared cache reports the same
    /// numbers a private one would.
    pub fn run_with_cache(
        &self,
        graph: &Graph,
        cluster: &Cluster,
        inits: &[SearchPoint],
        external: Option<(&TemplateCache, u64)>,
    ) -> Result<SearchResult> {
        if inits.is_empty() {
            return Err(Error::InvalidStrategy(
                "search needs at least one seed point".into(),
            ));
        }
        let cfg = self.config;
        if cfg.chains == 0 {
            return Err(Error::InvalidStrategy("search needs ≥ 1 chain".into()));
        }
        let t0 = Instant::now();
        let deadline = cfg.wall_s.map(|s| t0 + std::time::Duration::from_secs_f64(s));
        let gamma = calibrate::default_gamma(cluster);
        let own = if external.is_none() {
            cfg.compile_cache.then(TemplateCache::new)
        } else {
            None
        };
        let cache: Option<(&TemplateCache, u64)> = if cfg.compile_cache {
            external.or_else(|| own.as_ref().map(|c| (c, 0)))
        } else {
            None
        };
        let before = cache.map(|(c, _)| c.snapshot()).unwrap_or_default();

        // Even budget split: chain i gets ⌈budget/chains⌉ or ⌊…⌋.
        let budgets: Vec<usize> = (0..cfg.chains)
            .map(|i| cfg.budget / cfg.chains + usize::from(i < cfg.budget % cfg.chains))
            .collect();

        let auto = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let requested = if cfg.threads > 0 { cfg.threads } else { auto };
        let threads = requested.clamp(1, cfg.chains);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<ChainReport>>> =
            (0..cfg.chains).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cfg.chains {
                        break;
                    }
                    let report = run_chain(
                        graph,
                        cluster,
                        gamma,
                        &cfg,
                        i,
                        budgets[i],
                        &inits[i % inits.len()],
                        cache,
                        deadline,
                    );
                    *slots[i].lock().unwrap() = Some(report);
                });
            }
        });

        let chains: Vec<ChainReport> = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker filled every chain"))
            .collect();
        // Deterministic cross-chain winner: best throughput, ties on
        // label, then chain order (stable iteration).
        let mut best: Option<Evaluation> = None;
        for c in &chains {
            if let Some(e) = &c.best {
                let better = match &best {
                    None => true,
                    Some(b) => match e.throughput.total_cmp(&b.throughput) {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => e.label < b.label,
                    },
                };
                if better {
                    best = Some(e.clone());
                }
            }
        }
        let delta = cache
            .map(|(c, _)| c.snapshot().since(before))
            .unwrap_or_default();
        Ok(SearchResult {
            best,
            evals: chains.iter().map(|c| c.evals).sum(),
            delta_hits: chains.iter().map(|c| c.delta_hits).sum(),
            full_compiles: chains.iter().map(|c| c.full_compiles).sum(),
            bound_prunes: chains.iter().map(|c| c.bound_prunes).sum(),
            chains,
            wall_s: t0.elapsed().as_secs_f64(),
            cache_hits: delta.hits,
            cache_misses: delta.misses,
        })
    }
}

/// Score one point through the sweep-shared path.
fn evaluate(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    plain: bool,
    cache: Option<(&TemplateCache, u64)>,
    point: &SearchPoint,
) -> Evaluation {
    let tree = point.spec.build(graph);
    evaluate_built(
        graph, cluster, gamma, plain, cache, point, &tree, None, false, false,
    )
    .0
}

/// [`evaluate`] over a pre-built tree, with the delta-compile hooks:
/// `parent` is the current point's emit record (delta resume source),
/// `want_record` requests this candidate's own record for the next hop.
/// Scoring is bit-identical regardless of those two arguments.
#[allow(clippy::too_many_arguments)]
fn evaluate_built(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    plain: bool,
    cache: Option<(&TemplateCache, u64)>,
    point: &SearchPoint,
    tree: &Result<StrategyTree>,
    parent: Option<&EmitRecord>,
    want_record: bool,
    fold: bool,
) -> (Evaluation, Option<EmitRecord>) {
    let label = point.label();
    fn fail(point: &SearchPoint, label: &str, e: String) -> Evaluation {
        Evaluation {
            point: point.clone(),
            label: label.to_string(),
            step_ms: f64::INFINITY,
            throughput: 0.0,
            peak_mem: 0,
            oom: false,
            error: Some(e),
            fold_classes: 0,
            fold_devices_folded: 0,
            fold_fallback: false,
        }
    }
    let tree = match tree {
        Ok(t) => t,
        Err(e) => return (fail(point, &label, e.to_string()), None),
    };
    let (s, record) = score_tree_delta_opts(
        graph,
        cluster,
        gamma,
        tree,
        plain,
        point.coll_algo,
        cache,
        parent,
        want_record,
        fold,
    );
    let eval = match s.report {
        Ok(r) => Evaluation {
            point: point.clone(),
            label,
            step_ms: r.step_ms,
            throughput: r.throughput,
            peak_mem: r.peak_mem.iter().copied().max().unwrap_or(0),
            oom: r.oom,
            error: None,
            fold_classes: s.fold_classes,
            fold_devices_folded: s.fold_devices_folded,
            fold_fallback: s.fold_fallback,
        },
        Err(e) => fail(point, &label, e),
    };
    (eval, record)
}

/// Draw a neighbor of `point`: usually a tree mutation, occasionally
/// (1 in 8, when enabled) a collective-algorithm swap.
fn propose_point(
    graph: &Graph,
    point: &SearchPoint,
    rng: &mut Rng,
    mutate_coll: bool,
) -> Option<SearchPoint> {
    if mutate_coll && rng.chance(0.125) {
        let algos = [
            CollAlgo::Ring,
            CollAlgo::Tree,
            CollAlgo::Hierarchical,
            CollAlgo::Auto,
        ];
        let pick = *rng.pick(&algos);
        if pick != point.coll_algo {
            return Some(SearchPoint {
                spec: point.spec.clone(),
                coll_algo: pick,
            });
        }
        // No-op draw: fall through to a tree mutation.
    }
    propose(graph, &point.spec, rng, 64).map(|(_, spec)| SearchPoint {
        spec,
        coll_algo: point.coll_algo,
    })
}

/// One annealing chain: deterministic given its seed and budget.
#[allow(clippy::too_many_arguments)]
fn run_chain(
    graph: &Graph,
    cluster: &Cluster,
    gamma: f64,
    cfg: &SearchConfig,
    chain: usize,
    budget: usize,
    init: &SearchPoint,
    cache: Option<(&TemplateCache, u64)>,
    deadline: Option<Instant>,
) -> ChainReport {
    let seed = cfg.seed.wrapping_add(chain as u64);
    let mut report = ChainReport {
        chain,
        seed,
        evals: 0,
        accepted: 0,
        infeasible: 0,
        delta_hits: 0,
        full_compiles: 0,
        bound_prunes: 0,
        best: None,
    };
    if budget == 0 {
        return report;
    }
    let mut rng = Rng::new(seed);
    let init_tree = init.spec.build(graph);
    let mut cur_hashes = stage_hashes_of(graph, &init_tree);
    let (mut cur, mut cur_rec) = evaluate_built(
        graph,
        cluster,
        gamma,
        cfg.plain,
        cache,
        init,
        &init_tree,
        None,
        cfg.delta,
        cfg.fold,
    );
    report.evals = 1;
    report.full_compiles = 1;
    if cur.feasible() {
        report.best = Some(cur.clone());
    } else {
        report.infeasible = 1;
    }
    // Pruned proposals cost no simulation, so the eval budget alone
    // cannot bound the loop — cap total proposals to keep a chain whose
    // whole neighborhood prunes from spinning forever.
    let max_proposals = std::cmp::max(64, budget.saturating_mul(16));
    let mut proposals = 0usize;
    while report.evals < budget && proposals < max_proposals {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        let Some(next) = propose_point(graph, &cur.point, &mut rng, cfg.mutate_coll) else {
            break; // neighborhood exhausted
        };
        proposals += 1;
        let tree = next.spec.build(graph);
        let resolved = tree.as_ref().ok().and_then(|t| resolve(graph, t).ok());
        // Branch-and-bound: a neighbor whose admissible lower bound
        // already exceeds the chain's best feasible energy cannot
        // improve it — skip the simulation (and the accept draw)
        // entirely.
        if cfg.prune {
            if let (Some(r), Some(best)) = (resolved.as_ref(), report.best.as_ref()) {
                let bound = htae_lower_bound_ms(graph, cluster, r, next.coll_algo);
                if bound > best.step_ms {
                    report.bound_prunes += 1;
                    continue;
                }
            }
        }
        // Classify the proposal against the current point by per-stage
        // hash prefix. This is deliberately independent of `cfg.delta`
        // (and of what the compiler actually reuses), so counters and
        // JSON output diff byte-identical between delta and no-delta
        // runs.
        let hashes = resolved
            .as_ref()
            .map(|r| r.stage_hashes(graph, CLASSIFY_SEED));
        let prefix = match (&cur_hashes, &hashes) {
            (Some(a), Some(b)) => a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count(),
            _ => 0,
        };
        if prefix >= 1 {
            report.delta_hits += 1;
        } else {
            report.full_compiles += 1;
        }
        let (cand, cand_rec) = evaluate_built(
            graph,
            cluster,
            gamma,
            cfg.plain,
            cache,
            &next,
            &tree,
            if cfg.delta { cur_rec.as_ref() } else { None },
            cfg.delta,
            cfg.fold,
        );
        report.evals += 1;
        // Geometric cooling over the chain's budget.
        let progress = report.evals as f64 / budget.max(2) as f64;
        let temp = cfg.t0 * (cfg.t1 / cfg.t0).powf(progress);
        if cand.feasible() {
            let accept = if !cur.feasible() || cand.step_ms <= cur.step_ms {
                true
            } else {
                let delta = (cand.step_ms - cur.step_ms) / cur.step_ms;
                rng.next_f64() < (-delta / temp.max(1e-12)).exp()
            };
            let better_than_best = report
                .best
                .as_ref()
                .map(|b| cand.throughput > b.throughput)
                .unwrap_or(true);
            if better_than_best {
                report.best = Some(cand.clone());
            }
            if accept {
                cur = cand;
                cur_rec = cand_rec;
                cur_hashes = hashes;
                report.accepted += 1;
            }
        } else {
            report.infeasible += 1;
            // Both infeasible: drift toward lower peak memory so a
            // chain seeded out-of-memory can walk back into range.
            if !cur.feasible()
                && cand.error.is_none()
                && (cur.error.is_some() || cand.peak_mem < cur.peak_mem)
            {
                cur = cand;
                cur_rec = cand_rec;
                cur_hashes = hashes;
                report.accepted += 1;
            }
        }
    }
    report
}

/// Per-stage classification hashes of a built tree (`None` when the
/// build or resolve failed — such points classify every neighbor as a
/// full compile).
fn stage_hashes_of(graph: &Graph, tree: &Result<StrategyTree>) -> Option<Vec<u64>> {
    tree.as_ref()
        .ok()
        .and_then(|t| resolve(graph, t).ok())
        .map(|r| r.stage_hashes(graph, CLASSIFY_SEED))
}

/// Heuristic seed points for a search over `n_devices` GPUs at the
/// model's batch size: pure data parallelism, the classic `dp × mp`
/// and pipelined hybrids, filtered to the ones the model/batch admits.
/// Always non-empty (full replication is the last resort), and every
/// point uses the whole device budget — mutations conserve it.
pub fn default_inits(graph: &Graph, n_devices: usize, coll_algo: CollAlgo) -> Vec<SearchPoint> {
    fn push(graph: &Graph, out: &mut Vec<SearchPoint>, coll: CollAlgo, spec: StrategySpec) {
        if let Ok(nu) = NonUniformSpec::from_uniform(graph, spec) {
            out.push(SearchPoint {
                spec: nu,
                coll_algo: coll,
            });
        }
    }
    let n = n_devices.max(1);
    let mut out = Vec::new();
    push(graph, &mut out, coll_algo, StrategySpec::data_parallel(n));
    if n % 2 == 0 {
        push(
            graph,
            &mut out,
            coll_algo,
            StrategySpec::hybrid(n / 2, 2, 1, 1),
        );
        push(
            graph,
            &mut out,
            coll_algo,
            StrategySpec::hybrid(n / 2, 1, 2, 4),
        );
    }
    if n % 4 == 0 {
        push(
            graph,
            &mut out,
            coll_algo,
            StrategySpec::hybrid(n / 4, 1, 4, 8),
        );
    }
    if n % 8 == 0 {
        push(
            graph,
            &mut out,
            coll_algo,
            StrategySpec::hybrid(n / 8, 8, 1, 1),
        );
    }
    if out.is_empty() {
        // Full replication: valid for any model/batch, uses the budget.
        out.push(SearchPoint {
            spec: NonUniformSpec::single_stage(graph, 1, n),
            coll_algo,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Preset;
    use crate::graph::{DType, GraphBuilder};

    fn mlp(batch: usize, blocks: usize) -> Graph {
        let mut b = GraphBuilder::new("mlp", batch);
        let mut h = b.input("x", &[batch, 64], DType::F32);
        for i in 0..blocks {
            h = b.scoped(&format!("blk{i}"), |b| {
                let h = b.linear("fc1", h, 64, 256);
                let h = b.relu("act", h);
                let h = b.linear("fc2", h, 256, 64);
                b.hint_last(crate::graph::MpHint::RowSplit);
                h
            });
        }
        let _ = b.loss("loss", h);
        b.finish()
    }

    fn small_setup() -> (Graph, Cluster, Vec<SearchPoint>) {
        let g = mlp(16, 4);
        let c = Cluster::preset(Preset::HC1, 1);
        let inits = default_inits(&g, 4, CollAlgo::Auto);
        (g, c, inits)
    }

    #[test]
    fn default_inits_are_valid_and_nonempty() {
        let g = mlp(16, 4);
        for n in [1usize, 2, 4, 8] {
            let inits = default_inits(&g, n, CollAlgo::Auto);
            assert!(!inits.is_empty(), "n={n}");
            for p in &inits {
                assert_eq!(p.spec.n_devices(), n, "{}", p.label());
                p.spec.build(&g).expect("init builds");
            }
        }
        // Odd device counts fall back to replication.
        let inits = default_inits(&g, 3, CollAlgo::Ring);
        assert_eq!(inits.len(), 1);
        assert_eq!(inits[0].spec.n_devices(), 3);
        assert_eq!(inits[0].coll_algo, CollAlgo::Ring);
    }

    #[test]
    fn seeded_search_is_bit_reproducible() {
        let (g, c, inits) = small_setup();
        let cfg = SearchConfig {
            budget: 24,
            chains: 2,
            seed: 7,
            ..SearchConfig::default()
        };
        let a = Searcher::new(cfg).run(&g, &c, &inits).unwrap();
        let b = Searcher::new(cfg).run(&g, &c, &inits).unwrap();
        let ba = a.best.clone().unwrap();
        let bb = b.best.clone().unwrap();
        assert_eq!(ba.label, bb.label);
        assert_eq!(ba.step_ms.to_bits(), bb.step_ms.to_bits());
        assert_eq!(ba.throughput.to_bits(), bb.throughput.to_bits());
        assert_eq!(a.evals, b.evals);
        for (ca, cb) in a.chains.iter().zip(&b.chains) {
            assert_eq!(ca.accepted, cb.accepted);
            assert_eq!(ca.infeasible, cb.infeasible);
            assert_eq!(
                ca.best.as_ref().map(|e| e.label.clone()),
                cb.best.as_ref().map(|e| e.label.clone())
            );
        }
        // And thread count must not matter.
        let serial = Searcher::new(SearchConfig { threads: 1, ..cfg })
            .run(&g, &c, &inits)
            .unwrap();
        assert_eq!(serial.best.unwrap().label, ba.label);
    }

    /// Tentpole pin: symmetry folding never changes what a seeded
    /// search finds — the walk (accept decisions, counters, winner) is
    /// bit-identical with folding on or off, because folded scoring
    /// bit-matches unfolded scoring and fallback covers the rest.
    #[test]
    fn seeded_search_identical_with_and_without_fold() {
        let (g, c, inits) = small_setup();
        let cfg = SearchConfig {
            budget: 24,
            chains: 2,
            seed: 11,
            ..SearchConfig::default()
        };
        let plain = Searcher::new(cfg).run(&g, &c, &inits).unwrap();
        let folded = Searcher::new(SearchConfig { fold: true, ..cfg })
            .run(&g, &c, &inits)
            .unwrap();
        assert_eq!(plain.evals, folded.evals);
        assert_eq!(plain.bound_prunes, folded.bound_prunes);
        let (bp, bf) = (plain.best.unwrap(), folded.best.unwrap());
        assert_eq!(bp.label, bf.label);
        assert_eq!(bp.step_ms.to_bits(), bf.step_ms.to_bits());
        assert_eq!(bp.throughput.to_bits(), bf.throughput.to_bits());
        assert_eq!(bp.peak_mem, bf.peak_mem);
        for (ca, cb) in plain.chains.iter().zip(&folded.chains) {
            assert_eq!(ca.accepted, cb.accepted);
            assert_eq!(ca.infeasible, cb.infeasible);
        }
    }

    #[test]
    fn search_respects_budget_and_finds_feasible_points() {
        let (g, c, inits) = small_setup();
        let cfg = SearchConfig {
            budget: 30,
            chains: 3,
            seed: 1,
            ..SearchConfig::default()
        };
        let r = Searcher::new(cfg).run(&g, &c, &inits).unwrap();
        assert!(r.evals <= 30);
        assert!(r.evals >= 3, "each chain evaluates at least its init");
        let best = r.best.expect("a 4-GPU MLP has feasible strategies");
        assert!(best.feasible());
        assert!(best.throughput > 0.0);
        // The winner must never regress below the evaluated seed point.
        let gamma = calibrate::default_gamma(&c);
        let seed_eval = evaluate(&g, &c, gamma, false, None, &inits[0]);
        assert!(best.throughput >= seed_eval.throughput - 1e-9);
    }

    #[test]
    fn search_rejects_empty_inits_and_zero_chains() {
        let (g, c, inits) = small_setup();
        assert!(Searcher::new(SearchConfig::default())
            .run(&g, &c, &[])
            .is_err());
        let cfg = SearchConfig {
            chains: 0,
            ..SearchConfig::default()
        };
        assert!(Searcher::new(cfg).run(&g, &c, &inits).is_err());
    }

    #[test]
    fn uniform_seed_point_scores_identically_to_sweep_path() {
        use crate::models::ModelKind;
        use crate::runtime::sweep::{Scenario, SweepRunner};
        let model = ModelKind::Vgg19;
        let (batch, preset, nodes) = (16, Preset::HC1, 1);
        let spec = StrategySpec::data_parallel(2);
        let sc = Scenario {
            model: crate::models::ModelSpec::preset(model),
            batch,
            preset,
            nodes,
            spec,
        };
        let outcomes = SweepRunner::new().with_threads(1).run(&[sc]);
        let sweep_tput = outcomes[0].throughput().unwrap();
        let g = model.build(batch);
        let c = Cluster::preset(preset, nodes);
        let gamma = calibrate::default_gamma(&c);
        let point = SearchPoint::from_uniform(&g, spec).unwrap();
        let e = evaluate(&g, &c, gamma, false, None, &point);
        assert!(e.feasible(), "{:?}", e.error);
        assert_eq!(e.throughput.to_bits(), sweep_tput.to_bits());
    }
}
