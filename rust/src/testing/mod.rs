//! In-tree property-based testing framework.
//!
//! The offline vendored crate set has no `proptest`/`quickcheck`, so this
//! module provides a small deterministic substitute used by the test
//! suites: a seeded generator handle ([`Gen`]) plus a [`check`] driver
//! that runs a property across many generated cases and reports the
//! failing seed for exact reproduction.
//!
//! There is no shrinking; instead every case is tagged with `(base_seed,
//! case_index)` so a failure message pinpoints one deterministic input —
//! rerun with [`check_seeded`] to debug.

use crate::util::rng::Rng;

/// Generator handle passed to properties. Wraps the deterministic PRNG
/// with convenience constructors for common shapes of test data.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Construct from a raw seed.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Uniform u64 in `[lo, hi]`.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// A power of two in `[1, max]` (max need not be a power of two).
    pub fn pow2_upto(&mut self, max: usize) -> usize {
        debug_assert!(max >= 1);
        let maxexp = (usize::BITS - 1 - max.leading_zeros()) as usize;
        1usize << self.usize_in(0, maxexp)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick an element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }

    /// Pick an index into a collection of length `len`.
    pub fn index(&mut self, len: usize) -> usize {
        debug_assert!(len > 0);
        self.usize_in(0, len - 1)
    }

    /// Generate a vector of `n` items.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }

    /// Access the underlying PRNG for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Run `prop` against `cases` generated inputs derived from `base_seed`.
/// Panics with the failing `(base_seed, case)` pair on the first failure.
pub fn check_with_seed(
    name: &str,
    base_seed: u64,
    cases: usize,
    mut prop: impl FnMut(&mut Gen) -> PropResult,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Run a property with the default seed and case count (256).
pub fn check(name: &str, prop: impl FnMut(&mut Gen) -> PropResult) {
    check_with_seed(name, 0xC0FFEE, 256, prop)
}

/// Re-run a single failing case by seed (debug helper).
pub fn check_seeded(name: &str, seed: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// Build a minimal execution graph of independent tasks (no dependency
/// edges, no memory events) for simulator unit tests that need exact
/// control over task payloads — e.g. a single collective in isolation.
pub fn adhoc_exec_graph(
    tasks: Vec<crate::compiler::Task>,
    n_devices: usize,
) -> crate::compiler::ExecGraph {
    let n = tasks.len();
    crate::compiler::ExecGraph::from_tasks(
        tasks,
        vec![Vec::new(); n],
        vec![0; n],
        crate::compiler::ExecMeta {
            n_stages: 1,
            n_devices,
            static_mem: vec![0; n_devices],
            batch: 1,
            stage_schedule: Vec::new(),
        },
    )
}

/// Wrap a task payload with neutral metadata for [`adhoc_exec_graph`].
pub fn adhoc_task(kind: crate::compiler::TaskKind) -> crate::compiler::Task {
    crate::compiler::Task {
        kind,
        layer: None,
        stage: 0,
        micro: 0,
        phase: crate::compiler::Phase::Bwd,
        allocs: Vec::new(),
        frees: Vec::new(),
    }
}

/// Assert two floats are within relative tolerance.
pub fn assert_close(a: f64, b: f64, rel: f64) -> PropResult {
    let denom = b.abs().max(1e-30);
    if ((a - b) / denom).abs() <= rel {
        Ok(())
    } else {
        Err(format!("{a} vs {b} exceeds rel tol {rel}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("x+0=x", |g| {
            let x = g.u64_in(0, 1_000_000);
            if x + 0 == x {
                Ok(())
            } else {
                Err("math is broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failures() {
        check("always-fails", |_| Err("nope".into()));
    }

    #[test]
    fn pow2_upto_is_a_power_of_two_and_bounded() {
        check("pow2", |g| {
            let max = g.usize_in(1, 1000);
            let p = g.pow2_upto(max);
            if p.is_power_of_two() && p <= max.next_power_of_two() {
                Ok(())
            } else {
                Err(format!("p={p} max={max}"))
            }
        });
    }

    #[test]
    fn u64_in_respects_bounds() {
        check("u64_in", |g| {
            let lo = g.u64_in(0, 100);
            let hi = lo + g.u64_in(0, 100);
            let x = g.u64_in(lo, hi);
            if x >= lo && x <= hi {
                Ok(())
            } else {
                Err(format!("{x} outside [{lo},{hi}]"))
            }
        });
    }

    #[test]
    fn assert_close_behaves() {
        assert!(assert_close(1.0, 1.0005, 1e-3).is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3).is_err());
    }
}
