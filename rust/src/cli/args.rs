//! Minimal command-line argument parser (offline build: no `clap`).
//!
//! Grammar: `proteus <command> [--key value]... [--flag]...`. Values
//! never start with `--`; unknown keys are rejected so typos fail loudly.
//! The [`HELP`] text lives next to the parser so the documented surface
//! and the grammar stay in one file; `proteus help` and a `--help` flag
//! on any command print it.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// The `proteus help` / `--help` text. Every option listed here is
/// consumed by a command in `cli::run` (and vice versa — the
/// `reject_unknown` pass makes undocumented stragglers fail loudly).
pub const HELP: &str = "\
Proteus-RS: simulating the performance of distributed DNN training.

USAGE: proteus <command> [options]

COMMANDS:
  simulate    Predict throughput/memory of one (model, strategy, cluster)
  compare     Sweep the strategies of a JSON experiment config
  sweep       Rank an exhaustive strategy grid in parallel (SweepRunner)
  search      Simulated-annealing search over non-uniform strategy trees
  serve       Daemon: NDJSON requests on stdin, one JSON response per
              line on stdout, concurrent on a warm session
              ([--threads N], 0 = one worker per core)
  calibrate   Measure the overlap factor gamma per hardware preset
  info        Print a model's structure statistics
  bench-cost  Benchmark the PJRT vs analytical cost backends
  help        This message (also: --help on any command)

WORKLOAD OPTIONS (simulate, sweep, search, info):
  --model NAME      preset model; accepted names (with aliases):
                    resnet50|resnet, inception_v3|inception, vgg19|vgg,
                    gpt2|gpt-2, gpt1.5b|gpt-1.5b|gpt15b, dlrm,
                    moe-gpt|moe_gpt, moe-llama-7b|moe_llama_7b
  --model-file PATH load a custom layer graph from a JSON file instead
                    of a preset (format: examples/models/mlp.json;
                    mutually exclusive with --model and size knobs)
  --layers N        override block count (GPT and MoE presets only)
  --hidden N        override hidden size (GPT and MoE presets only)
  --experts N       override expert count (MoE presets only)
  --batch N         global batch size
  --preset <HC1|HC2|HC3|HC4>  hardware preset (HC4: rail-optimized
                    multi-NIC fat tree, up to 512 nodes)
  --nodes N         nodes of the preset to instantiate
  --nics N          override NICs per node (rail-optimized fabric)
  --oversub R       fat-tree oversubscription ratio (default 1.0 =
                    non-blocking; R > 1 thins the trunk by R)

STRATEGY OPTIONS (simulate):
  --dp N --mp N --pp N --micro N   parallel degrees + micro-batches
  --ep N            expert-parallel degree (MoE models; the device
                    budget is dp*mp*pp*ep, so EP trades against the
                    dense degrees rather than adding devices)
  --moe-imbalance F token-imbalance factor delta >= 0 (simulate): the
                    hottest expert receives (1+delta)x its balanced
                    token share; inflates hot-expert compute and the
                    all-to-all payload (default 0 = balanced router)
  --schedule <gpipe|1f1b|interleaved[:v]>
                    pipeline execution order (default 1f1b)
  --vstages N       virtual stages per device for interleaved (default 2)
  --zero            ZeRO parameter/optimizer sharding
  --recompute       activation recomputation
  --emb-shard       shard embedding tables (DLRM expert strategy)

SWEEP OPTIONS:
  --schedules <all|gpipe|1f1b|interleaved[:v]|a,b,...>
                    schedule set to enumerate for pipelined candidates
                    (default 1f1b)
  --threads N       worker threads (0 = auto; search: capped at chains)
  --top N           ranked rows to print (default 10)

SEARCH OPTIONS:
  --seed N          base RNG seed (default 42); a fixed seed makes the
                    whole search bit-reproducible
  --budget N        total simulation budget across chains (default 200)
  --chains K        independent annealing chains (default 4)
  --init LABEL      seed every chain from a uniform spec label, e.g.
                    4x2x2(8)+1f1b+zero (default: heuristic expert set)
  --resume FILE     seed from the 'best' of a previous --json output
  --fixed-coll      do not mutate the collective algorithm
  --no-delta        disable delta re-compilation (A/B knob; results are
                    bit-identical with or without it)
  --no-prune        disable bound-based proposal pruning (changes the
                    walk: pruned proposals are never simulated)
  --wall-secs S     optional wall-clock cap (breaks reproducibility)

SCALE (simulate, sweep, search):
  --fold            symmetry folding: compile + simulate one
                    representative replica slice when device-equivalence
                    verification passes (bit-identical results; falls
                    back to the unfolded graph when unprovable)

COLLECTIVES (simulate, sweep, search):
  --coll-algo <ring|tree|hier|auto|mono>
                    collective-algorithm lowering (default auto):
                    flat ring, binomial tree, NCCL-style 2-level
                    hierarchy, automatic per-collective selection by
                    message size and group span, or the monolithic
                    alpha-beta ablation path (fig9)

OUTPUT / VALIDATION:
  --json            machine-readable JSON on stdout (simulate, sweep,
                    search; schemas documented in README.md)
  --no-timings      omit wall-clock fields from --json (simulate, sweep):
                    the remaining document is the stable, byte-
                    reproducible schema subset serve responses use
  --compact         print --json documents on one line (the serve
                    response body format)
  --compile-stats   print per-pass compiler timings and counters
                    (template/weave/instantiate/finalize; simulate)
  --plain           disable runtime-behavior modeling (ablation)
  --truth           also run the flow-level testbed emulator
  --no-coalesce     truth run without serial-chain coalescing (simulate;
                    results are bit-identical — CI diffs the documents)
  --legacy-scan     truth run dispatches with the pre-worklist full
                    device scan (simulate; debug knob, bit-identical)
  --flexflow        also run the FlexFlow-Sim baseline (simulate)
  --trace FILE      write a Chrome/Perfetto trace of the HTAE timeline
  --artifacts PATH  AOT cost-kernel artifact (default artifacts/costmodel.hlo.txt)

OTHER:
  calibrate --out FILE   write calibrated gammas as JSON
  compare --config FILE  experiment config (see configs/ examples)
  info --model M [--batch N]
  bench-cost [--rows N] [--artifacts PATH]
";

/// Parsed arguments: a command plus key→value options and boolean flags.
#[derive(Debug, Default)]
pub struct Args {
    /// Subcommand (first positional).
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Keys the command actually consumed (for unknown-key detection).
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse(raw: impl Iterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let raw: Vec<String> = raw.collect();
        let mut i = 0;
        if i < raw.len() && !raw[i].starts_with("--") {
            args.command = raw[i].clone();
            i += 1;
        }
        while i < raw.len() {
            let a = &raw[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --option, got '{a}'")))?
                .to_string();
            if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                args.opts.insert(key, raw[i + 1].clone());
                i += 2;
            } else {
                args.flags.push(key);
                i += 1;
            }
        }
        Ok(args)
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// usize option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    /// f64 option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not a number"))),
        }
    }

    /// Boolean flag.
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag never consumed by the command.
    pub fn reject_unknown(&self) -> Result<()> {
        let used = self.used.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !used.iter().any(|u| u == k) {
                return Err(Error::Config(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_opts_and_flags() {
        let a = parse("simulate --model gpt2 --dp 4 --truth");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("model"), Some("gpt2"));
        assert_eq!(a.get_usize("dp", 1).unwrap(), 4);
        assert!(a.flag("truth"));
        assert!(!a.flag("plain"));
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.get_or("preset", "HC1"), "HC1");
        assert_eq!(a.get_usize("mp", 1).unwrap(), 1);
    }

    #[test]
    fn rejects_bad_integers() {
        let a = parse("simulate --dp four");
        assert!(a.get_usize("dp", 1).is_err());
    }

    #[test]
    fn rejects_unknown_options() {
        let a = parse("simulate --bogus 3");
        let _ = a.get("model");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn rejects_non_option_garbage() {
        assert!(Args::parse(
            ["simulate".to_string(), "garbage".to_string()].into_iter()
        )
        .is_err());
    }
}
