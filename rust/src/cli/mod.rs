//! Command-line interface: the launcher a user drives the simulator
//! with.
//!
//! ```text
//! proteus simulate  --model gpt2 --batch 64 --preset HC2 --nodes 2
//!                   --dp 4 --mp 2 --pp 2 --micro 4 [--ep 4]
//!                   [--model-file graph.json]
//!                   [--layers N] [--hidden N] [--experts N]
//!                   [--nics N] [--oversub R] [--fold]
//!                   [--schedule gpipe|1f1b|interleaved[:v]] [--vstages N]
//!                   [--zero] [--recompute] [--emb-shard] [--plain]
//!                   [--moe-imbalance 0.2]
//!                   [--truth] [--json] [--no-timings] [--compact]
//!                   [--trace out.json]
//!                   [--artifacts artifacts/costmodel.hlo.txt]
//! proteus compare   --config configs/gpt2_hc2.json [--truth]
//! proteus sweep     --model moe-gpt --batch 64 --preset HC2 --nodes 2
//!                   [--schedules all|gpipe|1f1b|interleaved[:v]]
//!                   [--nics N] [--oversub R] [--fold]
//!                   [--threads N] [--top 10] [--plain] [--truth] [--json]
//! proteus search    --model gpt2 --batch 64 --preset HC2 --nodes 2
//!                   [--seed 42] [--budget 200] [--chains 4] [--threads N]
//!                   [--init LABEL | --resume FILE] [--fixed-coll]
//!                   [--no-delta] [--no-prune] [--fold]
//!                   [--nics N] [--oversub R]
//!                   [--wall-secs S] [--plain] [--json]
//! proteus serve     [--threads N]
//! proteus calibrate [--out configs/gamma.json]
//! proteus info      --model resnet50 [--batch 32]
//! proteus bench-cost [--rows 65536] [--artifacts ...]
//! ```
//!
//! This module is a thin shell: it parses flags into the typed request
//! structs of [`crate::session`], runs them against one
//! [`Session`], and formats the typed responses — every compile and
//! simulate happens inside the session layer, which `proteus serve`
//! shares for long-lived concurrent use. The full flag reference is
//! [`args::HELP`]; the `--json` output schemas are documented in the
//! repo README.

pub mod args;

use crate::cluster::Preset;
use crate::collective::CollAlgo;
use crate::models::{ModelKind, ModelSpec};
use crate::session::{
    parse_schedules, spec_from_json, SearchInit, SearchRequest, Session, SimulateRequest,
    SweepRequest,
};
use crate::strategy::{PipelineSchedule, StrategySpec};
use crate::util::fmt_bytes;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::{Error, Result};

pub use crate::session::DEFAULT_ARTIFACT;
pub use args::{Args, HELP};

/// Entry point: dispatch a parsed command line. Every command runs
/// against one fresh [`Session`]; `proteus serve` keeps that session
/// alive across many requests.
pub fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        print!("{}", HELP);
        return Ok(());
    }
    let session = Session::new();
    match args.command.as_str() {
        "simulate" => cmd_simulate(args, &session),
        "compare" => cmd_compare(args, &session),
        "sweep" => cmd_sweep(args, &session),
        "search" => cmd_search(args, &session),
        "serve" => cmd_serve(args, &session),
        "calibrate" => cmd_calibrate(args, &session),
        "info" => cmd_info(args, &session),
        "bench-cost" => cmd_bench_cost(args, &session),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command '{other}' (try 'proteus help')"
        ))),
    }
}

/// Parse the workload model: `--model NAME` (optionally resized with
/// `--layers/--hidden/--experts`, GPT / MoE families only) or
/// `--model-file PATH` (an external JSON layer graph, see
/// `models::import`). The two selectors are mutually exclusive, and the
/// resize knobs only apply to presets.
fn parse_model(args: &Args, default: &str) -> Result<ModelSpec> {
    let opt = |key: &str| -> Result<Option<usize>> {
        match args.get(key) {
            None => Ok(None),
            Some(_) => args.get_usize(key, 0).map(Some),
        }
    };
    let (layers, hidden, experts) = (opt("layers")?, opt("hidden")?, opt("experts")?);
    if let Some(path) = args.get("model-file") {
        if args.get("model").is_some() {
            return Err(Error::Config(
                "--model and --model-file are mutually exclusive".into(),
            ));
        }
        if layers.is_some() || hidden.is_some() || experts.is_some() {
            return Err(Error::Config(
                "--layers/--hidden/--experts resize presets, not --model-file graphs".into(),
            ));
        }
        return ModelSpec::from_file(&path.to_string());
    }
    let name = args.get_or("model", default);
    let kind = ModelKind::parse(&name)
        .ok_or_else(|| Error::Config(format!("unknown model '{name}'")))?;
    if layers.is_none() && hidden.is_none() && experts.is_none() {
        return Ok(ModelSpec::preset(kind));
    }
    // Knob validation (family restriction, head divisibility) happens in
    // ModelSpec::build; probe at batch 1 so bad knobs fail at the flag
    // boundary rather than deep inside a sweep.
    let spec = ModelSpec::Preset {
        kind,
        layers,
        hidden,
        experts,
    };
    spec.build(1)?;
    Ok(spec)
}

/// Parse the `(model, batch, preset, nodes, spec)` workload shared by
/// commands. Cluster construction happens inside the session (memoized
/// per `(preset, nodes, fabric)`), so this stays pure flag-parsing.
fn parse_workload(args: &Args) -> Result<(ModelSpec, usize, Preset, usize, StrategySpec)> {
    let model = parse_model(args, "gpt2")?;
    let batch = args.get_usize("batch", 8)?;
    let preset = args.get_or("preset", "HC1");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", preset.max_nodes())?;
    let mut spec = StrategySpec::hybrid(
        args.get_usize("dp", 1)?,
        args.get_usize("mp", 1)?,
        args.get_usize("pp", 1)?,
        args.get_usize("micro", 1)?,
    );
    spec.moe = args.get_usize("ep", 1)?;
    spec.zero = args.flag("zero");
    spec.recompute = args.flag("recompute");
    spec.shard_embeddings = args.flag("emb-shard");
    let sched = args.get_or("schedule", "1f1b");
    let mut sched = PipelineSchedule::parse(&sched)
        .ok_or_else(|| Error::Config(format!("unknown schedule '{sched}'")))?;
    if let Some(vs) = args.get("vstages") {
        let v: usize = vs
            .parse()
            .map_err(|_| Error::Config(format!("--vstages: '{vs}' is not an integer")))?;
        if v == 0 {
            return Err(Error::Config("--vstages must be ≥ 1".into()));
        }
        match sched {
            PipelineSchedule::Interleaved { .. } => {
                sched = PipelineSchedule::Interleaved { v };
            }
            _ => {
                return Err(Error::Config(
                    "--vstages requires --schedule interleaved".into(),
                ))
            }
        }
    }
    spec.schedule = sched;
    Ok((model, batch, preset, nodes, spec))
}

/// Parse the optional `--nics` / `--oversub` fabric overrides.
fn fabric_overrides(args: &Args) -> Result<(Option<usize>, Option<f64>)> {
    let nics = match args.get("nics") {
        None => None,
        Some(n) => Some(n.parse().map_err(|_| {
            Error::Config(format!("--nics: '{n}' is not an integer"))
        })?),
    };
    let oversub = match args.get("oversub") {
        None => None,
        Some(_) => Some(args.get_f64("oversub", 1.0)?),
    };
    Ok((nics, oversub))
}

/// Parse `--coll-algo` (collective lowering override; `auto` selects
/// ring/tree/hierarchical per collective, `mono` is the monolithic
/// ablation path).
fn parse_coll_algo(args: &Args) -> Result<CollAlgo> {
    let s = args.get_or("coll-algo", "auto");
    CollAlgo::parse(&s).ok_or_else(|| {
        Error::Config(format!(
            "unknown collective algorithm '{s}' (ring|tree|hier|auto|mono)"
        ))
    })
}

/// Print a `--json` document honoring `--compact`.
fn print_doc(doc: &Json, compact: bool) {
    if compact {
        println!("{}", doc.to_string_compact());
    } else {
        println!("{}", doc.to_string_pretty());
    }
}

/// Text rendering of `--compile-stats`: per-pass timings and task/dep
/// counts (the same counters `benches/perf_hotpath.rs` reads).
fn print_compile_stats(s: &crate::compiler::CompileStats) {
    println!(
        "compile passes: template={:.2}ms{} weave={:.2}ms instantiate={:.2}ms finalize={:.2}ms",
        s.template_s * 1e3,
        if s.cache_hit { " (cache hit)" } else { "" },
        s.weave_s * 1e3,
        s.instantiate_s * 1e3,
        s.finalize_s * 1e3,
    );
    println!(
        "  template: {} segments → {} slots, {} tasks + {} preamble, \
         {} layer emissions, {} transform inferences",
        s.n_segments,
        s.template_slots,
        s.template_tasks,
        s.preamble_tasks,
        s.template_layer_emissions,
        s.template_transforms,
    );
    println!(
        "  instantiated: {} micro-batches × {} chunks → {} tasks, {} deps",
        s.n_micro, s.n_chunks, s.n_tasks, s.n_deps,
    );
    if s.coalesce_chains > 0 {
        println!(
            "  coalesce: {} serial chains absorb {} extra comp tasks",
            s.coalesce_chains, s.coalesce_fused_tasks,
        );
    }
    if s.fold_classes > 0 {
        println!(
            "  fold: {} device classes, {} devices elided — {} logical tasks \
             materialized as {} ({:.2}ms)",
            s.fold_classes,
            s.fold_devices_folded,
            s.logical_tasks,
            s.n_tasks,
            s.fold_s * 1e3,
        );
    } else if s.fold_fallback {
        println!(
            "  fold: fallback to unfolded graph (symmetry unprovable, {:.2}ms)",
            s.fold_s * 1e3
        );
    }
}

/// Base field list of the `proteus simulate --json` document (schema in
/// README.md) with the wall-clock fields included. Kept as a stable
/// entry point for the fold differential harness
/// (`tests/differential_fold.rs`), which renders the document with
/// pinned wall-clock values and byte-compares a folded run against an
/// unfolded one; the canonical builder is
/// [`crate::session::simulate_fields`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_json(
    model: &str,
    strategy: String,
    schedule: String,
    coll_algo: CollAlgo,
    cluster_name: &str,
    gpus: usize,
    backend: &str,
    logical_tasks: usize,
    compile_s: f64,
    simulate_s: f64,
    report: &crate::executor::SimReport,
) -> Vec<(&'static str, Json)> {
    crate::session::simulate_fields(
        model,
        strategy,
        schedule,
        coll_algo,
        cluster_name,
        gpus,
        backend,
        logical_tasks,
        Some((compile_s, simulate_s)),
        report,
    )
}

/// Build the `proteus search --json` document — a stable entry point
/// for the delta differential harness (`tests/differential_search.rs`);
/// the canonical builder is [`crate::session::search_doc`].
#[allow(clippy::too_many_arguments)]
pub fn search_json(
    model: &str,
    batch: usize,
    cluster_name: &str,
    gpus: usize,
    seed: u64,
    budget: usize,
    n_chains: usize,
    coll_algo: CollAlgo,
    result: &crate::runtime::SearchResult,
) -> Json {
    crate::session::search_doc(
        model,
        batch,
        cluster_name,
        gpus,
        seed,
        budget,
        n_chains,
        coll_algo,
        result,
    )
}

fn cmd_simulate(args: &Args, session: &Session) -> Result<()> {
    let (model, batch, preset, nodes, spec) = parse_workload(args)?;
    let (nics, oversub) = fabric_overrides(args)?;
    let plain = args.flag("plain");
    let truth = args.flag("truth");
    let no_coalesce = args.flag("no-coalesce");
    let legacy_scan = args.flag("legacy-scan");
    let flexflow = args.flag("flexflow");
    let json = args.flag("json");
    let compile_stats = args.flag("compile-stats");
    let no_timings = args.flag("no-timings");
    let compact = args.flag("compact");
    let fold = args.flag("fold");
    let coll_algo = parse_coll_algo(args)?;
    let moe_imbalance = args.get_f64("moe-imbalance", 0.0)?;
    if moe_imbalance < 0.0 {
        return Err(Error::Config(format!(
            "--moe-imbalance {moe_imbalance}: the token-imbalance factor must be ≥ 0"
        )));
    }
    let trace_path = args.get("trace").map(|s| s.to_string());
    // Read --artifacts before the unknown-option pass: reading it only
    // after reject_unknown() made `simulate --artifacts PATH` fail as
    // an unknown option even though HELP documents it.
    let artifacts = args.get_or("artifacts", DEFAULT_ARTIFACT);
    args.reject_unknown()?;

    let req = SimulateRequest {
        model,
        batch,
        preset,
        nodes,
        nics,
        oversub,
        spec,
        plain,
        truth,
        no_coalesce,
        legacy_scan,
        flexflow,
        fold,
        coll_algo,
        moe_imbalance,
        trace: trace_path.is_some(),
        artifacts,
    };
    let resp = session.simulate(&req)?;

    if json {
        // Schema documented in README.md ("JSON output").
        print_doc(&resp.to_json(!no_timings, compile_stats), compact);
    } else {
        println!(
            "model={} strategy={} cluster={}({} GPUs) backend={} coll={}",
            resp.model,
            resp.strategy,
            resp.cluster,
            resp.gpus,
            resp.backend,
            resp.coll_algo.name(),
        );
        println!(
            "tasks={} compile={:.3}s simulate={:.3}s",
            resp.logical_tasks, resp.compile_s, resp.simulate_s
        );
        if resp.stats.fold_classes > 0 {
            println!(
                "folded: {} device classes, {} devices elided, {} tasks materialized",
                resp.stats.fold_classes,
                resp.stats.fold_devices_folded,
                resp.stats.n_tasks,
            );
        } else if resp.stats.fold_fallback {
            println!("folded: fallback to unfolded graph (symmetry unprovable)");
        }
        println!(
            "step={:.2} ms  throughput={:.1} samples/s  oom={}  peak_mem={}",
            resp.report.step_ms,
            resp.report.throughput,
            resp.report.oom,
            fmt_bytes(resp.report.peak_mem.iter().copied().max().unwrap_or(0)),
        );
        println!(
            "behaviors: {} overlapped comps, {} bandwidth-shared comms",
            resp.report.overlapped_ops, resp.report.shared_ops
        );
        if compile_stats {
            print_compile_stats(&resp.stats);
        }
        if let Some(t) = &resp.truth {
            println!(
                "emulator(truth): step={:.2} ms throughput={:.1}  HTAE error={:.2}%",
                t.step_ms,
                t.throughput,
                crate::util::rel_err_pct(resp.report.step_ms, t.step_ms)
            );
            if compile_stats {
                if let Some(e) = t.engine {
                    println!(
                        "  engine: {} events popped ({} stale), {} scan iters, \
                         {} flows re-rated, {} chains fused",
                        e.events_popped,
                        e.stale_discards,
                        e.device_scan_iters,
                        e.flows_rerated,
                        e.chains_fused,
                    );
                }
            }
        }
        if let Some(ff) = &resp.flexflow {
            match ff {
                Ok(step_ms) => println!("flexflow-sim: step={step_ms:.2} ms"),
                Err(e) => println!("flexflow-sim: unsupported ({e})"),
            }
        }
    }
    if let Some(path) = trace_path {
        // `req.trace` was set, so the response carries the rendered
        // trace document; written compact like `write_chrome_trace`.
        let trace = resp.trace.as_ref().expect("trace requested but not rendered");
        std::fs::write(&path, trace.to_string_compact())?;
        if !json {
            println!("trace written to {path}");
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args, session: &Session) -> Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| Error::Config("compare requires --config FILE".into()))?
        .to_string();
    let truth = args.flag("truth");
    // Like cmd_simulate: --artifacts must be consumed before the
    // unknown-option pass.
    let artifacts = args.get_or("artifacts", DEFAULT_ARTIFACT);
    args.reject_unknown()?;
    let text = std::fs::read_to_string(&path)?;
    let doc = Json::parse(&text).map_err(|e| Error::Config(e.to_string()))?;
    let model = doc
        .get("model")
        .and_then(|v| v.as_str())
        .and_then(ModelSpec::parse)
        .ok_or_else(|| Error::Config("config: bad 'model'".into()))?;
    let batch = doc
        .get("batch")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| Error::Config("config: bad 'batch'".into()))?;
    let preset = doc
        .get("preset")
        .and_then(|v| v.as_str())
        .and_then(Preset::parse)
        .ok_or_else(|| Error::Config("config: bad 'preset'".into()))?;
    let nodes = doc
        .get("nodes")
        .and_then(|v| v.as_usize())
        .unwrap_or(preset.max_nodes());
    let strategies = doc
        .get("strategies")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Config("config: 'strategies' must be an array".into()))?;
    let specs: Vec<StrategySpec> = strategies
        .iter()
        .map(spec_from_json)
        .collect::<Result<_>>()?;

    let resp = session.compare(&model, batch, preset, nodes, &specs, truth, &artifacts)?;
    let mut table = Table::new(&if truth {
        vec!["strategy", "step_ms", "samples/s", "oom", "truth_ms", "err%"]
    } else {
        vec!["strategy", "step_ms", "samples/s", "oom"]
    });
    for row in &resp.rows {
        let mut cells = vec![
            row.strategy.clone(),
            format!("{:.2}", row.step_ms),
            format!("{:.1}", row.throughput),
            row.oom.to_string(),
        ];
        if let Some((truth_ms, err_pct)) = row.truth {
            cells.push(format!("{truth_ms:.2}"));
            cells.push(format!("{err_pct:.2}"));
        }
        table.row(cells);
    }
    println!(
        "{} batch={} on {} ({} GPUs)",
        resp.model, resp.batch, resp.cluster, resp.gpus
    );
    print!("{}", table.render());
    Ok(())
}

/// Simulated-annealing search over non-uniform strategy trees
/// (`runtime::search`): the simulator as an optimizer, not just a
/// scorer.
fn cmd_search(args: &Args, session: &Session) -> Result<()> {
    let model = parse_model(args, "gpt2")?;
    let batch = args.get_usize("batch", 64)?;
    let preset = args.get_or("preset", "HC2");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let budget = args.get_usize("budget", 200)?;
    let chains = args.get_usize("chains", 4)?;
    let threads = args.get_usize("threads", 0)?;
    let plain = args.flag("plain");
    let json = args.flag("json");
    let compact = args.flag("compact");
    let coll_algo = parse_coll_algo(args)?;
    let fixed_coll = args.flag("fixed-coll");
    let init = args.get("init").map(str::to_string);
    let resume = args.get("resume").map(str::to_string);
    let no_delta = args.flag("no-delta");
    let no_prune = args.flag("no-prune");
    let wall_s = args
        .get("wall-secs")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| Error::Config(format!("--wall-secs: '{v}' is not a number")))
        })
        .transpose()?;
    let fold = args.flag("fold");
    let (nics, oversub) = fabric_overrides(args)?;
    args.reject_unknown()?;

    // The file I/O stays in the CLI; the session validates the resumed
    // spec against this request's workload.
    let init = if let Some(path) = resume {
        let text = std::fs::read_to_string(&path)?;
        let doc = Json::parse(&text).map_err(|e| Error::Config(e.to_string()))?;
        SearchInit::Resume { doc, origin: path }
    } else if let Some(label) = init {
        SearchInit::Label(label)
    } else {
        SearchInit::Default
    };
    let req = SearchRequest {
        model,
        batch,
        preset,
        nodes,
        nics,
        oversub,
        seed,
        budget,
        chains,
        threads,
        plain,
        coll_algo,
        mutate_coll: !fixed_coll,
        delta: !no_delta,
        prune: !no_prune,
        wall_s,
        fold,
        init,
    };
    let resp = session.search(&req)?;

    if json {
        print_doc(&resp.to_json(), compact);
        return Ok(());
    }

    let result = &resp.result;
    println!(
        "searched {} candidates for {} b={} on {}({} GPUs): {} chains, seed {} — {:.2}s \
         (template cache: {} misses, {} hits; delta hits {}, full compiles {}, \
         bound-pruned {})",
        result.evals,
        resp.model,
        resp.batch,
        resp.cluster,
        resp.gpus,
        resp.chains,
        resp.seed,
        result.wall_s,
        result.cache_misses,
        result.cache_hits,
        result.delta_hits,
        result.full_compiles,
        result.bound_prunes,
    );
    let mut table = Table::new(&[
        "chain",
        "evals",
        "accepted",
        "infeasible",
        "delta",
        "full",
        "pruned",
        "best samples/s",
        "best strategy",
    ]);
    for c in &result.chains {
        table.row(vec![
            c.chain.to_string(),
            c.evals.to_string(),
            c.accepted.to_string(),
            c.infeasible.to_string(),
            c.delta_hits.to_string(),
            c.full_compiles.to_string(),
            c.bound_prunes.to_string(),
            c.best
                .as_ref()
                .map(|e| format!("{:.1}", e.throughput))
                .unwrap_or_else(|| "-".into()),
            c.best
                .as_ref()
                .map(|e| e.label.clone())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.render());
    match &result.best {
        Some(b) => {
            println!(
                "best: {}  {:.1} samples/s ({:.2} ms/step), peak mem {}",
                b.label,
                b.throughput,
                b.step_ms,
                fmt_bytes(b.peak_mem),
            );
            if b.fold_classes > 0 {
                println!(
                    "fold: {} device classes, {} devices elided",
                    b.fold_classes, b.fold_devices_folded
                );
            }
            println!("spec: {}", b.point.spec.to_json());
        }
        None => println!("no feasible strategy found within budget"),
    }
    Ok(())
}

/// Rank an exhaustive strategy grid with the parallel
/// [`crate::runtime::SweepRunner`].
fn cmd_sweep(args: &Args, session: &Session) -> Result<()> {
    let model = parse_model(args, "gpt2")?;
    let batch = args.get_usize("batch", 64)?;
    let preset = args.get_or("preset", "HC2");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", 2)?;
    let threads = args.get_usize("threads", 0)?;
    let top = args.get_usize("top", 10)?;
    let plain = args.flag("plain");
    let truth = args.flag("truth");
    let json = args.flag("json");
    let no_timings = args.flag("no-timings");
    let compact = args.flag("compact");
    let fold = args.flag("fold");
    let coll_algo = parse_coll_algo(args)?;
    let schedules = parse_schedules(&args.get_or("schedules", "1f1b"))?;
    let artifacts = args.get_or("artifacts", DEFAULT_ARTIFACT);
    let (nics, oversub) = fabric_overrides(args)?;
    args.reject_unknown()?;

    let req = SweepRequest {
        model,
        batch,
        preset,
        nodes,
        nics,
        oversub,
        schedules,
        threads,
        top,
        plain,
        truth,
        fold,
        coll_algo,
        artifacts,
    };
    let resp = session.sweep(&req)?;

    if json {
        // Schema documented in README.md ("JSON output").
        print_doc(&resp.to_json(!no_timings), compact);
        return Ok(());
    }
    println!(
        "swept {} strategies for {} b={} on {}({} GPUs): {} viable, {} OOM, {} invalid, \
         {} duplicates dropped — {:.2?} on {} threads",
        resp.outcomes.len(),
        resp.model,
        resp.batch,
        resp.cluster,
        resp.gpus,
        resp.n_viable(),
        resp.n_oom(),
        resp.n_invalid(),
        resp.deduped,
        resp.wall,
        resp.threads,
    );
    let mut table = Table::new(&["rank", "strategy", "step_ms", "samples/s", "oom"]);
    for (i, o) in resp.ranked().iter().take(resp.top).enumerate() {
        let r = o.report.as_ref().unwrap();
        table.row(vec![
            (i + 1).to_string(),
            o.scenario.spec.label(),
            format!("{:.2}", r.step_ms),
            format!("{:.1}", r.throughput),
            if o.oom { "OOM".into() } else { "-".to_string() },
        ]);
    }
    print!("{}", table.render());
    if resp.fold {
        let folded = resp.outcomes.iter().filter(|o| o.fold_classes > 0).count();
        let fell_back = resp.outcomes.iter().filter(|o| o.fold_fallback).count();
        println!(
            "fold: {folded} candidates folded, {fell_back} fell back to the unfolded graph"
        );
    }
    for t in resp.truth.iter().flatten() {
        println!(
            "truth {}: {:.2} ms ({:.1} samples/s), HTAE error {:.2}%",
            t.strategy, t.step_ms, t.throughput, t.err_pct
        );
    }
    Ok(())
}

/// The `proteus serve` daemon: NDJSON requests on stdin, one JSON
/// response per line on stdout, concurrent requests sharing this
/// process's warm [`Session`] (protocol documented in README.md and
/// [`crate::session::serve`]).
fn cmd_serve(args: &Args, session: &Session) -> Result<()> {
    let threads = args.get_usize("threads", 0)?;
    args.reject_unknown()?;
    let stats = crate::session::serve(
        session,
        std::io::stdin().lock(),
        std::io::stdout(),
        threads,
    )?;
    // The summary goes to stderr: stdout carries only response lines.
    eprintln!("served {} requests ({} errors)", stats.requests, stats.errors);
    Ok(())
}

fn cmd_calibrate(args: &Args, session: &Session) -> Result<()> {
    let out = args.get("out").map(|s| s.to_string());
    args.reject_unknown()?;
    let resp = session.calibrate()?;
    let mut table = Table::new(&["preset", "device", "gamma"]);
    for r in &resp.rows {
        table.row(vec![
            r.preset.into(),
            r.device.clone(),
            format!("{:.4}", r.gamma),
        ]);
    }
    print!("{}", table.render());
    if let Some(path) = out {
        let doc = Json::obj(
            resp.rows
                .iter()
                .map(|r| (r.preset, Json::Num(r.gamma)))
                .collect(),
        );
        std::fs::write(&path, doc.to_string_pretty())?;
        println!("written to {path}");
    }
    Ok(())
}

fn cmd_info(args: &Args, session: &Session) -> Result<()> {
    let model = parse_model(args, "gpt2")?;
    let batch = args.get_usize("batch", 8)?;
    args.reject_unknown()?;
    let resp = session.info(&model, batch)?;
    println!("model={} batch={}", resp.model, resp.batch);
    println!("layers={} tensors={}", resp.layers, resp.tensors);
    println!("params={:.1}M", resp.params as f64 / 1e6);
    println!(
        "fwd_flops={:.2} GFLOP/step",
        resp.fwd_flops as f64 / 1e9
    );
    Ok(())
}

fn cmd_bench_cost(args: &Args, session: &Session) -> Result<()> {
    let rows = args.get_usize("rows", 65536)?;
    let path = args.get_or("artifacts", DEFAULT_ARTIFACT);
    args.reject_unknown()?;
    let resp = session.bench_cost(rows, &path)?;
    println!(
        "analytical: {rows} rows in {:?} ({:.1} Mrows/s)",
        resp.wall_analytical,
        rows as f64 / resp.wall_analytical.as_secs_f64() / 1e6
    );
    match &resp.pjrt {
        Some(p) => {
            println!(
                "pjrt:       {rows} rows in {:?} ({:.1} Mrows/s)",
                p.wall,
                rows as f64 / p.wall.as_secs_f64() / 1e6
            );
            println!("max backend divergence: {:.2e}", p.max_rel);
        }
        None => println!("pjrt:       skipped ({path} missing; run `make artifacts`)"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn workload_parsing_defaults() {
        let a = parse("simulate --model vgg19 --batch 32 --dp 4");
        let (m, b, p, nodes, s) = parse_workload(&a).unwrap();
        assert_eq!(m, ModelSpec::preset(ModelKind::Vgg19));
        assert_eq!(b, 32);
        assert_eq!(p, Preset::HC1);
        assert_eq!(nodes, Preset::HC1.max_nodes());
        assert_eq!(s.dp, 4);
        assert_eq!(s.mp, 1);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let a = parse("simulate --model resnet152");
        assert!(parse_workload(&a).is_err());
    }

    #[test]
    fn spec_from_json_reads_all_fields() {
        let j = Json::parse(
            r#"{"dp":2,"mp":4,"pp":2,"micro":8,"zero":true,"recompute":true,"emb_shard":true}"#,
        )
        .unwrap();
        let s = spec_from_json(&j).unwrap();
        assert_eq!((s.dp, s.mp, s.pp, s.n_micro_batch), (2, 4, 2, 8));
        assert!(s.zero && s.recompute && s.shard_embeddings);
    }

    #[test]
    fn schedule_flags_parse() {
        let a = parse("simulate --pp 2 --micro 4 --schedule gpipe");
        let (_, _, _, _, s) = parse_workload(&a).unwrap();
        assert_eq!(s.schedule, PipelineSchedule::GpipeFillDrain);
        let a = parse("simulate --pp 2 --micro 4 --schedule interleaved --vstages 3");
        let (_, _, _, _, s) = parse_workload(&a).unwrap();
        assert_eq!(s.schedule, PipelineSchedule::Interleaved { v: 3 });
        let a = parse("simulate --schedule 2f2b");
        assert!(parse_workload(&a).is_err());
        // --vstages is inert without interleaved; that must fail loudly.
        let a = parse("simulate --pp 2 --vstages 4");
        assert!(parse_workload(&a).is_err());
        // Explicit 0 is rejected like interleaved:0, not silently kept.
        let a = parse("simulate --pp 2 --schedule interleaved --vstages 0");
        assert!(parse_workload(&a).is_err());
    }

    #[test]
    fn schedules_set_parses() {
        assert_eq!(parse_schedules("all").unwrap(), PipelineSchedule::all());
        assert_eq!(
            parse_schedules("gpipe,1f1b").unwrap(),
            vec![PipelineSchedule::GpipeFillDrain, PipelineSchedule::OneFOneB]
        );
        assert!(parse_schedules("bogus").is_err());
    }

    #[test]
    fn help_flag_short_circuits() {
        let a = parse("simulate --help");
        run(&a).unwrap();
    }

    #[test]
    fn unknown_command_fails() {
        let a = parse("frobnicate");
        assert!(run(&a).is_err());
    }

    #[test]
    fn info_command_runs() {
        let a = parse("info --model resnet50 --batch 8");
        run(&a).unwrap();
    }

    #[test]
    fn coll_algo_flag_parses_and_runs() {
        for algo in ["ring", "tree", "hier", "auto", "mono"] {
            let a = parse(&format!(
                "simulate --model vgg19 --batch 16 --preset HC2 --nodes 2 --dp 16 \
                 --coll-algo {algo} --json"
            ));
            run(&a).unwrap();
        }
        let a = parse("simulate --model vgg19 --batch 8 --coll-algo bogus");
        assert!(run(&a).is_err());
    }

    #[test]
    fn compile_stats_flag_runs_in_both_output_modes() {
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 4 \
             --compile-stats",
        );
        run(&a).unwrap();
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 4 \
             --compile-stats --json",
        );
        run(&a).unwrap();
    }

    /// Regression: `--artifacts` is documented for simulate/compare but
    /// was read only *after* `reject_unknown()`, so passing it failed
    /// with "unknown option --artifacts". It must be consumed up front
    /// (a missing artifact file falls back to the analytical backend,
    /// so pointing at a nonexistent path still runs).
    #[test]
    fn artifacts_flag_is_consumed_not_rejected() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --dp 2 \
             --artifacts /nonexistent/costmodel.hlo.txt --json",
        );
        run(&a).unwrap();

        let config = Json::obj(vec![
            ("model", Json::Str("vgg19".into())),
            ("batch", Json::Num(16.0)),
            ("preset", Json::Str("HC1".into())),
            ("nodes", Json::Num(1.0)),
            (
                "strategies",
                Json::Arr(vec![Json::obj(vec![("dp", Json::Num(2.0))])]),
            ),
        ]);
        let path = std::env::temp_dir().join(format!(
            "proteus_compare_artifacts_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, config.to_string_pretty()).unwrap();
        let a = parse(&format!(
            "compare --config {} --artifacts /nonexistent/costmodel.hlo.txt",
            path.display()
        ));
        let r = run(&a);
        std::fs::remove_file(&path).unwrap();
        r.unwrap();
    }

    /// `--no-timings` and `--compact` are accepted by the JSON-emitting
    /// commands (the schema subset itself is pinned by the session and
    /// serve tests).
    #[test]
    fn no_timings_and_compact_flags_run() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --dp 2 \
             --json --no-timings --compact",
        );
        run(&a).unwrap();
        let a = parse(
            "sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2 \
             --json --no-timings --compact",
        );
        run(&a).unwrap();
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --seed 3 --json --compact",
        );
        run(&a).unwrap();
    }

    /// Audit: every flag key the CLI reads through `Args` must appear
    /// in [`HELP`] as `--<key>`. The reader patterns are assembled at
    /// runtime so this test's own source never matches them.
    #[test]
    fn every_flag_read_by_the_cli_is_documented_in_help() {
        let src = concat!(include_str!("mod.rs"), "\n", include_str!("args.rs"));
        let readers = ["flag", "get", "get_or", "get_usize", "get_f64"];
        let mut keys = std::collections::BTreeSet::new();
        for m in readers {
            let needle = format!("args.{m}{}", "(\"");
            let mut rest = src;
            while let Some(i) = rest.find(&needle) {
                rest = &rest[i + needle.len()..];
                let Some(end) = rest.find('"') else { break };
                let key = &rest[..end];
                if !key.is_empty()
                    && key
                        .chars()
                        .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
                {
                    keys.insert(key.to_string());
                }
            }
        }
        assert!(keys.len() >= 30, "audit found too few keys: {keys:?}");
        for key in &keys {
            assert!(
                HELP.contains(&format!("--{key}")),
                "flag --{key} is read by the CLI but missing from HELP"
            );
        }
    }

    #[test]
    fn sweep_command_runs() {
        let a = parse("sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2");
        run(&a).unwrap();
    }

    #[test]
    fn sweep_command_enumerates_all_schedules_in_one_invocation() {
        let a = parse(
            "sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2 \
             --schedules all --json",
        );
        run(&a).unwrap();
    }

    #[test]
    fn search_command_runs_in_both_output_modes() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 8 --chains 2 \
             --seed 3",
        );
        run(&a).unwrap();
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 8 --chains 2 \
             --seed 3 --json",
        );
        run(&a).unwrap();
    }

    /// `--resume` must validate the loaded spec against the *current*
    /// `--preset/--nodes` device budget. Before the fix the mismatch
    /// only surfaced as a per-chain compile error deep inside the
    /// search (every chain silently infeasible); this pins the clean
    /// up-front `Config` error.
    #[test]
    fn search_resume_validates_device_budget() {
        use crate::strategy::NonUniformSpec;
        let g = ModelKind::Vgg19.build(16);
        // A best spec from a 32-GPU run: dp=4 × mp=8.
        let spec = NonUniformSpec::single_stage(&g, 4, 8);
        assert_eq!(spec.n_devices(), 32);
        let doc = Json::obj(vec![(
            "best",
            Json::obj(vec![
                ("label", Json::Str(spec.label())),
                ("coll_algo", Json::Str("auto".into())),
                ("spec", spec.to_json()),
            ]),
        )]);
        let path = std::env::temp_dir().join(format!(
            "proteus_resume_budget_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        // Resumed onto a single HC1 node — far fewer than 32 devices.
        let a = parse(&format!(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 4 --chains 1 \
             --resume {}",
            path.display()
        ));
        let err = run(&a).unwrap_err().to_string();
        std::fs::remove_file(&path).unwrap();
        assert!(err.contains("devices"), "unexpected error: {err}");
        assert!(err.contains("32"), "unexpected error: {err}");
    }

    #[test]
    fn search_no_delta_and_no_prune_flags_run() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --seed 3 --no-delta --no-prune --json",
        );
        run(&a).unwrap();
    }

    #[test]
    fn search_accepts_init_label_and_rejects_garbage() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --init 8x1x1(1)",
        );
        run(&a).unwrap();
        let a = parse("search --model vgg19 --batch 16 --init not-a-spec --budget 4");
        assert!(run(&a).is_err());
        let a = parse("search --model vgg19 --batch 16 --resume /nonexistent/search.json");
        assert!(run(&a).is_err());
    }

    /// `--fold` is accepted by all three strategy commands and runs end
    /// to end (the fold/unfold *equivalence* is pinned by
    /// `tests/differential_fold.rs` and the runtime unit tests; this is
    /// the CLI surface smoke).
    #[test]
    fn fold_flag_runs_across_commands() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC2 --nodes 2 --dp 16 --fold \
             --compile-stats --json",
        );
        run(&a).unwrap();
        let a = parse(
            "sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2 \
             --fold --json",
        );
        run(&a).unwrap();
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --seed 3 --fold --json",
        );
        run(&a).unwrap();
    }

    /// `--nics`/`--oversub` rebuild the preset fabric through the same
    /// validation as a hand-written [`crate::cluster::ClusterSpec`].
    #[test]
    fn fabric_overrides_parse_and_validate() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC4 --nodes 2 --dp 16 \
             --nics 4 --oversub 2.0 --json",
        );
        run(&a).unwrap();
        // More NICs than GPU ports on the node.
        let a = parse("simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --nics 64");
        assert!(run(&a).is_err());
        // Oversubscription below 1.0 would mint bandwidth.
        let a = parse("simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --oversub 0.5");
        assert!(run(&a).is_err());
        // Non-numeric values fail loudly.
        let a = parse("simulate --model vgg19 --batch 16 --nics many");
        assert!(run(&a).is_err());
        let a = parse("simulate --model vgg19 --batch 16 --oversub wide");
        assert!(run(&a).is_err());
    }

    #[test]
    fn simulate_json_with_explicit_schedule_runs() {
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 2 \
             --schedule gpipe --json",
        );
        run(&a).unwrap();
    }

    /// Audit: every model name `ModelKind::parse` accepts must be
    /// documented in [`HELP`] and in the repo README, so the open
    /// `ModelSpec` surface never grows an undocumented alias.
    #[test]
    fn every_model_alias_is_documented_in_help_and_readme() {
        let readme = include_str!("../../../README.md");
        for alias in ModelKind::aliases() {
            assert!(HELP.contains(alias), "model alias '{alias}' missing from HELP");
            assert!(
                readme.contains(alias),
                "model alias '{alias}' missing from README.md"
            );
        }
    }

    /// All preset names round-trip through the CLI parser.
    #[test]
    fn every_model_kind_parses_from_its_own_name() {
        for kind in ModelKind::all() {
            let a = parse(&format!("info --model {}", kind.name().to_lowercase()));
            let m = parse_model(&a, "gpt2").unwrap();
            assert_eq!(m, ModelSpec::preset(kind));
        }
    }

    /// Tentpole surface: `--ep` selects expert parallelism, and
    /// `--moe-imbalance` skews the router. Both validate at the flag
    /// boundary.
    #[test]
    fn moe_expert_parallel_simulate_runs() {
        let a = parse(
            "simulate --model moe-gpt --batch 8 --preset HC1 --nodes 1 --dp 4 --ep 2 --json",
        );
        run(&a).unwrap();
        // Skewed router: the hot expert gets 1.3x its balanced share.
        let a = parse(
            "simulate --model moe-gpt --batch 8 --preset HC1 --nodes 1 --dp 4 --ep 2 \
             --moe-imbalance 0.3 --json",
        );
        run(&a).unwrap();
        // A negative imbalance factor is rejected up front.
        let a = parse(
            "simulate --model moe-gpt --batch 8 --preset HC1 --nodes 1 --dp 4 --ep 2 \
             --moe-imbalance -0.5",
        );
        assert!(run(&a).is_err());
        // EP needs expert layers: gpt2 is dense.
        let a = parse("simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --dp 4 --ep 2");
        assert!(run(&a).is_err());
        // EP must divide the (overridden) expert count.
        let a = parse(
            "simulate --model moe-gpt --experts 4 --batch 8 --preset HC1 --nodes 1 \
             --dp 1 --ep 8",
        );
        assert!(run(&a).is_err());
    }

    #[test]
    fn moe_sweep_command_runs() {
        let a = parse(
            "sweep --model moe-gpt --batch 8 --preset HC1 --nodes 1 --top 3 --threads 2 --json",
        );
        run(&a).unwrap();
    }

    /// `--model-file` loads an external JSON layer graph; it is mutually
    /// exclusive with `--model` and with the preset resize knobs.
    #[test]
    fn model_file_flag_loads_and_simulates() {
        let path = std::env::temp_dir().join(format!(
            "proteus_cli_model_{}.json",
            std::process::id()
        ));
        std::fs::write(
            &path,
            r#"{"name":"mlp","input":[64],"layers":[{"op":"linear","out":128},{"op":"relu"},{"op":"linear","out":10}]}"#,
        )
        .unwrap();
        let a = parse(&format!(
            "simulate --model-file {} --batch 16 --preset HC1 --nodes 1 --dp 8 --json",
            path.display()
        ));
        let ok = run(&a);
        let a = parse(&format!(
            "simulate --model gpt2 --model-file {} --batch 16",
            path.display()
        ));
        let both_selectors = run(&a);
        let a = parse(&format!(
            "simulate --model-file {} --layers 2 --batch 16",
            path.display()
        ));
        let knob_on_file = run(&a);
        std::fs::remove_file(&path).unwrap();
        ok.unwrap();
        assert!(both_selectors.is_err());
        assert!(knob_on_file.is_err());
        // A missing file fails with a config error, not a panic.
        let a = parse("simulate --model-file /nonexistent/model.json --batch 16");
        assert!(run(&a).is_err());
    }

    /// `--layers/--hidden/--experts` resize the GPT / MoE presets and
    /// are rejected for models without those knobs.
    #[test]
    fn size_knobs_resize_presets() {
        let a = parse(
            "simulate --model gpt2 --layers 2 --batch 8 --preset HC1 --nodes 1 --dp 2 --json",
        );
        run(&a).unwrap();
        let a = parse("simulate --model vgg19 --layers 2 --batch 8");
        assert!(run(&a).is_err());
        let a = parse("simulate --model gpt2 --experts 4 --batch 8");
        assert!(run(&a).is_err());
    }
}
