//! Command-line interface: the launcher a user drives the simulator
//! with.
//!
//! ```text
//! proteus simulate  --model gpt2 --batch 64 --preset HC2 --nodes 2
//!                   --dp 4 --mp 2 --pp 2 --micro 4
//!                   [--nics N] [--oversub R] [--fold]
//!                   [--schedule gpipe|1f1b|interleaved[:v]] [--vstages N]
//!                   [--zero] [--recompute] [--emb-shard] [--plain]
//!                   [--truth] [--json] [--trace out.json]
//!                   [--artifacts artifacts/costmodel.hlo.txt]
//! proteus compare   --config configs/gpt2_hc2.json [--truth]
//! proteus sweep     --model gpt2 --batch 64 --preset HC2 --nodes 2
//!                   [--schedules all|gpipe|1f1b|interleaved[:v]]
//!                   [--nics N] [--oversub R] [--fold]
//!                   [--threads N] [--top 10] [--plain] [--truth] [--json]
//! proteus search    --model gpt2 --batch 64 --preset HC2 --nodes 2
//!                   [--seed 42] [--budget 200] [--chains 4] [--threads N]
//!                   [--init LABEL | --resume FILE] [--fixed-coll]
//!                   [--no-delta] [--no-prune] [--fold]
//!                   [--nics N] [--oversub R]
//!                   [--wall-secs S] [--plain] [--json]
//! proteus calibrate [--out configs/gamma.json]
//! proteus info      --model resnet50 [--batch 32]
//! proteus bench-cost [--rows 65536] [--artifacts ...]
//! ```
//!
//! The full flag reference is [`args::HELP`]; the `--json` output
//! schemas are documented in the repo README.

pub mod args;

use crate::baselines::FlexFlowSim;
use crate::cluster::{Cluster, Preset};
use crate::collective::CollAlgo;
use crate::emulator::{Emulator, EmulatorConfig};
use crate::estimator::OpEstimator;
use crate::executor::{calibrate, Htae, HtaeConfig};
use crate::models::ModelKind;
use crate::strategy::{build_strategy, PipelineSchedule, StrategySpec};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::{fmt_bytes, rel_err_pct};
use crate::{Error, Result};

pub use args::{Args, HELP};

/// Default artifact path.
pub const DEFAULT_ARTIFACT: &str = "artifacts/costmodel.hlo.txt";

/// Entry point: dispatch a parsed command line.
pub fn run(args: &Args) -> Result<()> {
    if args.flag("help") {
        print!("{}", HELP);
        return Ok(());
    }
    match args.command.as_str() {
        "simulate" => cmd_simulate(args),
        "compare" => cmd_compare(args),
        "sweep" => cmd_sweep(args),
        "search" => cmd_search(args),
        "calibrate" => cmd_calibrate(args),
        "info" => cmd_info(args),
        "bench-cost" => cmd_bench_cost(args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command '{other}' (try 'proteus help')"
        ))),
    }
}

/// Build the `(model, cluster, spec)` triple shared by commands.
fn parse_workload(args: &Args) -> Result<(ModelKind, usize, Cluster, StrategySpec)> {
    let model = args.get_or("model", "gpt2");
    let model = ModelKind::parse(&model)
        .ok_or_else(|| Error::Config(format!("unknown model '{model}'")))?;
    let batch = args.get_usize("batch", 8)?;
    let preset = args.get_or("preset", "HC1");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", preset.max_nodes())?;
    let cluster = build_cluster(args, preset, nodes)?;
    let mut spec = StrategySpec::hybrid(
        args.get_usize("dp", 1)?,
        args.get_usize("mp", 1)?,
        args.get_usize("pp", 1)?,
        args.get_usize("micro", 1)?,
    );
    spec.zero = args.flag("zero");
    spec.recompute = args.flag("recompute");
    spec.shard_embeddings = args.flag("emb-shard");
    let sched = args.get_or("schedule", "1f1b");
    let mut sched = PipelineSchedule::parse(&sched)
        .ok_or_else(|| Error::Config(format!("unknown schedule '{sched}'")))?;
    if let Some(vs) = args.get("vstages") {
        let v: usize = vs
            .parse()
            .map_err(|_| Error::Config(format!("--vstages: '{vs}' is not an integer")))?;
        if v == 0 {
            return Err(Error::Config("--vstages must be ≥ 1".into()));
        }
        match sched {
            PipelineSchedule::Interleaved { .. } => {
                sched = PipelineSchedule::Interleaved { v };
            }
            _ => {
                return Err(Error::Config(
                    "--vstages requires --schedule interleaved".into(),
                ))
            }
        }
    }
    spec.schedule = sched;
    Ok((model, batch, cluster, spec))
}

/// Parse the optional `--nics` / `--oversub` fabric overrides.
fn fabric_overrides(args: &Args) -> Result<(Option<usize>, Option<f64>)> {
    let nics = match args.get("nics") {
        None => None,
        Some(n) => Some(n.parse().map_err(|_| {
            Error::Config(format!("--nics: '{n}' is not an integer"))
        })?),
    };
    let oversub = match args.get("oversub") {
        None => None,
        Some(_) => Some(args.get_f64("oversub", 1.0)?),
    };
    Ok((nics, oversub))
}

/// Build the cluster for `preset` × `nodes`, applying the optional
/// `--nics` / `--oversub` fabric overrides. The overridden spec goes
/// back through [`Cluster::from_spec`], so an invalid combination
/// (more NICs than GPU ports, oversubscription below 1.0) fails with
/// the same validation errors a hand-written spec would.
fn build_cluster(args: &Args, preset: Preset, nodes: usize) -> Result<Cluster> {
    let (nics, oversub) = fabric_overrides(args)?;
    let mut spec = crate::cluster::presets::spec(preset, nodes);
    if let Some(k) = nics {
        spec.nics_per_node = k;
    }
    if let Some(r) = oversub {
        spec.oversubscription = r;
    }
    Cluster::from_spec(&spec)
}

/// Parse `--coll-algo` (collective lowering override; `auto` selects
/// ring/tree/hierarchical per collective, `mono` is the monolithic
/// ablation path).
fn parse_coll_algo(args: &Args) -> Result<CollAlgo> {
    let s = args.get_or("coll-algo", "auto");
    CollAlgo::parse(&s).ok_or_else(|| {
        Error::Config(format!(
            "unknown collective algorithm '{s}' (ring|tree|hier|auto|mono)"
        ))
    })
}

/// Parse the sweep's `--schedules` set.
fn parse_schedules(s: &str) -> Result<Vec<PipelineSchedule>> {
    if s == "all" {
        return Ok(PipelineSchedule::all());
    }
    s.split(',')
        .map(|tok| {
            PipelineSchedule::parse(tok.trim())
                .ok_or_else(|| Error::Config(format!("unknown schedule '{tok}'")))
        })
        .collect()
}

fn estimator<'c>(args: &Args, cluster: &'c Cluster) -> OpEstimator<'c> {
    let path = args.get_or("artifacts", DEFAULT_ARTIFACT);
    OpEstimator::best_available(cluster, &path)
}

/// Text rendering of `--compile-stats`: per-pass timings and task/dep
/// counts (the same counters `benches/perf_hotpath.rs` reads).
fn print_compile_stats(s: &crate::compiler::CompileStats) {
    println!(
        "compile passes: template={:.2}ms{} weave={:.2}ms instantiate={:.2}ms finalize={:.2}ms",
        s.template_s * 1e3,
        if s.cache_hit { " (cache hit)" } else { "" },
        s.weave_s * 1e3,
        s.instantiate_s * 1e3,
        s.finalize_s * 1e3,
    );
    println!(
        "  template: {} segments → {} slots, {} tasks + {} preamble, \
         {} layer emissions, {} transform inferences",
        s.n_segments,
        s.template_slots,
        s.template_tasks,
        s.preamble_tasks,
        s.template_layer_emissions,
        s.template_transforms,
    );
    println!(
        "  instantiated: {} micro-batches × {} chunks → {} tasks, {} deps",
        s.n_micro, s.n_chunks, s.n_tasks, s.n_deps,
    );
    if s.fold_classes > 0 {
        println!(
            "  fold: {} device classes, {} devices elided — {} logical tasks \
             materialized as {} ({:.2}ms)",
            s.fold_classes,
            s.fold_devices_folded,
            s.logical_tasks,
            s.n_tasks,
            s.fold_s * 1e3,
        );
    } else if s.fold_fallback {
        println!(
            "  fold: fallback to unfolded graph (symmetry unprovable, {:.2}ms)",
            s.fold_s * 1e3
        );
    }
}

/// JSON rendering of `--compile-stats` (schema in README).
fn compile_stats_json(s: &crate::compiler::CompileStats) -> Json {
    Json::obj(vec![
        ("template_s", Json::Num(s.template_s)),
        ("weave_s", Json::Num(s.weave_s)),
        ("instantiate_s", Json::Num(s.instantiate_s)),
        ("finalize_s", Json::Num(s.finalize_s)),
        ("cache_hit", Json::Bool(s.cache_hit)),
        ("segments", Json::Num(s.n_segments as f64)),
        ("template_slots", Json::Num(s.template_slots as f64)),
        ("template_tasks", Json::Num(s.template_tasks as f64)),
        ("preamble_tasks", Json::Num(s.preamble_tasks as f64)),
        (
            "template_layer_emissions",
            Json::Num(s.template_layer_emissions as f64),
        ),
        (
            "template_transforms",
            Json::Num(s.template_transforms as f64),
        ),
        ("n_micro", Json::Num(s.n_micro as f64)),
        ("n_chunks", Json::Num(s.n_chunks as f64)),
        ("tasks", Json::Num(s.n_tasks as f64)),
        ("deps", Json::Num(s.n_deps as f64)),
        ("logical_tasks", Json::Num(s.logical_tasks as f64)),
        ("fold_classes", Json::Num(s.fold_classes as f64)),
        (
            "fold_devices_folded",
            Json::Num(s.fold_devices_folded as f64),
        ),
        ("fold_fallback", Json::Bool(s.fold_fallback)),
        ("fold_s", Json::Num(s.fold_s)),
    ])
}

/// Base field list of the `proteus simulate --json` document (schema in
/// README.md). `cmd_simulate` appends the optional compile-stats /
/// truth / flexflow sections before printing. Exported so the fold
/// differential harness (`tests/differential_fold.rs`) can render the
/// document with pinned wall-clock fields and byte-compare a folded run
/// against an unfolded one: every field except the two wall-clock
/// timings is bit-deterministic, and `tasks` is the *logical* task
/// count, which folding preserves (the materialized count lives in
/// compile-stats).
#[allow(clippy::too_many_arguments)]
pub fn simulate_json(
    model: &str,
    strategy: String,
    schedule: String,
    coll_algo: CollAlgo,
    cluster_name: &str,
    gpus: usize,
    backend: &str,
    logical_tasks: usize,
    compile_s: f64,
    simulate_s: f64,
    report: &crate::executor::SimReport,
) -> Vec<(&'static str, Json)> {
    vec![
        ("model", Json::Str(model.into())),
        ("strategy", Json::Str(strategy)),
        ("schedule", Json::Str(schedule)),
        ("coll_algo", Json::Str(coll_algo.name().into())),
        ("cluster", Json::Str(cluster_name.into())),
        ("gpus", Json::Num(gpus as f64)),
        ("backend", Json::Str(backend.into())),
        ("tasks", Json::Num(logical_tasks as f64)),
        ("compile_s", Json::Num(compile_s)),
        ("simulate_s", Json::Num(simulate_s)),
        ("step_ms", Json::Num(report.step_ms)),
        ("throughput_samples_per_s", Json::Num(report.throughput)),
        ("oom", Json::Bool(report.oom)),
        (
            "peak_mem_bytes",
            Json::Arr(
                report
                    .peak_mem
                    .iter()
                    .map(|&b| Json::Num(b as f64))
                    .collect(),
            ),
        ),
        (
            "peak_act_bytes",
            Json::Arr(
                report
                    .peak_act
                    .iter()
                    .map(|&b| Json::Num(b as f64))
                    .collect(),
            ),
        ),
        ("overlapped_ops", Json::Num(report.overlapped_ops as f64)),
        ("shared_ops", Json::Num(report.shared_ops as f64)),
    ]
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (model, batch, cluster, spec) = parse_workload(args)?;
    let plain = args.flag("plain");
    let truth = args.flag("truth");
    let flexflow = args.flag("flexflow");
    let json = args.flag("json");
    let compile_stats = args.flag("compile-stats");
    let fold = args.flag("fold");
    let coll_algo = parse_coll_algo(args)?;
    let trace_path = args.get("trace").map(|s| s.to_string());
    args.reject_unknown()?;

    let graph = model.build(batch);
    let tree = build_strategy(&graph, spec)?;
    let t0 = std::time::Instant::now();
    let (eg, cstats) = crate::compiler::compile_with_opts(&graph, &tree, &cluster, None, fold)?;
    let compile_s = t0.elapsed().as_secs_f64();
    let est = estimator(args, &cluster);
    let mut config = if plain {
        HtaeConfig::plain()
    } else {
        HtaeConfig {
            gamma: calibrate::default_gamma(&cluster),
            ..HtaeConfig::default()
        }
    };
    config.coll_algo = coll_algo;
    config.record_timeline = trace_path.is_some();
    let t1 = std::time::Instant::now();
    let report = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
    let exe_s = t1.elapsed().as_secs_f64();
    let backend = if est.is_pjrt() { "pjrt" } else { "analytical" };
    // Run the optional validators once, up front, so the JSON and text
    // paths cannot drift. The emulated truth uses the same collective
    // lowering as the prediction.
    let truth_report = if truth {
        let emu_config = EmulatorConfig {
            coll_algo,
            ..EmulatorConfig::default()
        };
        Some(Emulator::with_config(&cluster, &est, emu_config).simulate(&eg)?)
    } else {
        None
    };
    let flexflow_report = if flexflow {
        Some(FlexFlowSim::new(&cluster).simulate(&graph, &tree, &eg))
    } else {
        None
    };

    if json {
        // Schema documented in README.md ("JSON output").
        let mut fields = simulate_json(
            model.name(),
            spec.label(),
            spec.schedule.name(),
            coll_algo,
            &cluster.name,
            cluster.num_devices(),
            backend,
            eg.logical_tasks(),
            compile_s,
            exe_s,
            &report,
        );
        if compile_stats {
            fields.push(("compile_stats", compile_stats_json(&cstats)));
        }
        if let Some(t) = &truth_report {
            fields.push((
                "truth",
                Json::obj(vec![
                    ("step_ms", Json::Num(t.step_ms)),
                    ("throughput_samples_per_s", Json::Num(t.throughput)),
                    ("err_pct", Json::Num(rel_err_pct(report.step_ms, t.step_ms))),
                ]),
            ));
        }
        if let Some(ff) = &flexflow_report {
            fields.push((
                "flexflow",
                match ff {
                    Ok(f) => Json::obj(vec![("step_ms", Json::Num(f.step_ms))]),
                    Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                },
            ));
        }
        println!("{}", Json::obj(fields).to_string_pretty());
    } else {
        println!(
            "model={} strategy={} cluster={}({} GPUs) backend={} coll={}",
            model.name(),
            spec.label(),
            cluster.name,
            cluster.num_devices(),
            backend,
            coll_algo.name(),
        );
        println!(
            "tasks={} compile={:.3}s simulate={:.3}s",
            eg.logical_tasks(),
            compile_s,
            exe_s
        );
        if let Some(f) = eg.fold() {
            println!(
                "folded: {} device classes, {} devices elided, {} tasks materialized",
                f.n_classes,
                f.devices_folded,
                eg.n_tasks(),
            );
        } else if cstats.fold_fallback {
            println!("folded: fallback to unfolded graph (symmetry unprovable)");
        }
        println!(
            "step={:.2} ms  throughput={:.1} samples/s  oom={}  peak_mem={}",
            report.step_ms,
            report.throughput,
            report.oom,
            fmt_bytes(report.peak_mem.iter().copied().max().unwrap_or(0)),
        );
        println!(
            "behaviors: {} overlapped comps, {} bandwidth-shared comms",
            report.overlapped_ops, report.shared_ops
        );
        if compile_stats {
            print_compile_stats(&cstats);
        }
        if let Some(t) = &truth_report {
            println!(
                "emulator(truth): step={:.2} ms throughput={:.1}  HTAE error={:.2}%",
                t.step_ms,
                t.throughput,
                rel_err_pct(report.step_ms, t.step_ms)
            );
        }
        if let Some(ff) = &flexflow_report {
            match ff {
                Ok(f) => println!("flexflow-sim: step={:.2} ms", f.step_ms),
                Err(e) => println!("flexflow-sim: unsupported ({e})"),
            }
        }
    }
    if let Some(path) = trace_path {
        crate::trace::write_chrome_trace(
            &path,
            &graph,
            &eg,
            &report.timeline,
            &report.comm_phases,
        )?;
        if !json {
            println!("trace written to {path}");
        }
    }
    Ok(())
}

/// Strategy entry of an experiment config file.
fn spec_from_json(j: &Json) -> Result<StrategySpec> {
    let g = |k: &str, d: usize| -> usize {
        j.get(k).and_then(|v| v.as_usize()).unwrap_or(d)
    };
    let mut spec = StrategySpec::hybrid(g("dp", 1), g("mp", 1), g("pp", 1), g("micro", 1));
    spec.zero = j.get("zero").and_then(|v| v.as_bool()).unwrap_or(false);
    spec.recompute = j.get("recompute").and_then(|v| v.as_bool()).unwrap_or(false);
    spec.shard_embeddings = j
        .get("emb_shard")
        .and_then(|v| v.as_bool())
        .unwrap_or(false);
    if let Some(s) = j.get("schedule").and_then(|v| v.as_str()) {
        spec.schedule = PipelineSchedule::parse(s)
            .ok_or_else(|| Error::Config(format!("config: unknown schedule '{s}'")))?;
    }
    Ok(spec)
}

fn cmd_compare(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| Error::Config("compare requires --config FILE".into()))?
        .to_string();
    let truth = args.flag("truth");
    args.reject_unknown()?;
    let text = std::fs::read_to_string(&path)?;
    let doc = Json::parse(&text).map_err(|e| Error::Config(e.to_string()))?;
    let model = doc
        .get("model")
        .and_then(|v| v.as_str())
        .and_then(ModelKind::parse)
        .ok_or_else(|| Error::Config("config: bad 'model'".into()))?;
    let batch = doc
        .get("batch")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| Error::Config("config: bad 'batch'".into()))?;
    let preset = doc
        .get("preset")
        .and_then(|v| v.as_str())
        .and_then(Preset::parse)
        .ok_or_else(|| Error::Config("config: bad 'preset'".into()))?;
    let nodes = doc
        .get("nodes")
        .and_then(|v| v.as_usize())
        .unwrap_or(preset.max_nodes());
    let cluster = Cluster::preset(preset, nodes);
    let strategies = doc
        .get("strategies")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Config("config: 'strategies' must be an array".into()))?;

    let graph = model.build(batch);
    let est = estimator(args, &cluster);
    let config = HtaeConfig {
        gamma: calibrate::default_gamma(&cluster),
        ..HtaeConfig::default()
    };
    let mut table = Table::new(&if truth {
        vec!["strategy", "step_ms", "samples/s", "oom", "truth_ms", "err%"]
    } else {
        vec!["strategy", "step_ms", "samples/s", "oom"]
    });
    for sj in strategies {
        let spec = spec_from_json(sj)?;
        let tree = build_strategy(&graph, spec)?;
        let eg = crate::compiler::compile(&graph, &tree, &cluster)?;
        let r = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
        let mut row = vec![
            spec.label(),
            format!("{:.2}", r.step_ms),
            format!("{:.1}", r.throughput),
            r.oom.to_string(),
        ];
        if truth {
            let t = Emulator::new(&cluster, &est).simulate(&eg)?;
            row.push(format!("{:.2}", t.step_ms));
            row.push(format!("{:.2}", rel_err_pct(r.step_ms, t.step_ms)));
        }
        table.row(row);
    }
    println!(
        "{} batch={} on {} ({} GPUs)",
        model.name(),
        batch,
        cluster.name,
        cluster.num_devices()
    );
    print!("{}", table.render());
    Ok(())
}

/// Simulated-annealing search over non-uniform strategy trees
/// (`runtime::search`): the simulator as an optimizer, not just a
/// scorer.
fn cmd_search(args: &Args) -> Result<()> {
    use crate::runtime::{default_inits, SearchConfig, SearchPoint, Searcher};
    use crate::strategy::NonUniformSpec;

    let model = args.get_or("model", "gpt2");
    let model = ModelKind::parse(&model)
        .ok_or_else(|| Error::Config(format!("unknown model '{model}'")))?;
    let batch = args.get_usize("batch", 64)?;
    let preset = args.get_or("preset", "HC2");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", 2)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let budget = args.get_usize("budget", 200)?;
    let chains = args.get_usize("chains", 4)?;
    let threads = args.get_usize("threads", 0)?;
    let plain = args.flag("plain");
    let json = args.flag("json");
    let coll_algo = parse_coll_algo(args)?;
    let fixed_coll = args.flag("fixed-coll");
    let init = args.get("init").map(str::to_string);
    let resume = args.get("resume").map(str::to_string);
    let no_delta = args.flag("no-delta");
    let no_prune = args.flag("no-prune");
    let wall_s = args
        .get("wall-secs")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| Error::Config(format!("--wall-secs: '{v}' is not a number")))
        })
        .transpose()?;
    let fold = args.flag("fold");
    let cluster = build_cluster(args, preset, nodes)?;
    args.reject_unknown()?;

    let n = cluster.num_devices();
    let graph = model.build(batch);

    // Seed points: a resumed best spec, an explicit uniform label, or
    // the heuristic expert set.
    let inits: Vec<SearchPoint> = if let Some(path) = resume {
        let text = std::fs::read_to_string(&path)?;
        let doc = Json::parse(&text).map_err(|e| Error::Config(e.to_string()))?;
        let best = doc
            .get("best")
            .filter(|b| **b != Json::Null)
            .ok_or_else(|| Error::Config(format!("{path}: no 'best' result to resume from")))?;
        let spec = best
            .get("spec")
            .ok_or_else(|| Error::Config(format!("{path}: 'best' has no 'spec'")))
            .and_then(NonUniformSpec::from_json)?;
        // The file records the spec, not the workload it was found on: a
        // resumed spec must be re-validated against *this* invocation's
        // device budget and model, and must fail cleanly here rather
        // than deep inside the first chain evaluation.
        if spec.n_devices() > n {
            return Err(Error::Config(format!(
                "{path}: resumed spec {} uses {} devices but {}x{nodes} provides {n}",
                spec.label(),
                spec.n_devices(),
                preset.name()
            )));
        }
        spec.validate(&graph).map_err(|e| {
            Error::Config(format!(
                "{path}: resumed spec {} is invalid for {} at batch {batch}: {e}",
                spec.label(),
                model.name()
            ))
        })?;
        let coll = best
            .get("coll_algo")
            .and_then(|v| v.as_str())
            .and_then(CollAlgo::parse)
            .unwrap_or(coll_algo);
        vec![SearchPoint {
            spec,
            coll_algo: coll,
        }]
    } else if let Some(label) = init {
        let uspec = StrategySpec::parse_label(&label)
            .ok_or_else(|| Error::Config(format!("--init: cannot parse spec label '{label}'")))?;
        vec![SearchPoint {
            spec: NonUniformSpec::from_uniform(&graph, uspec)?,
            coll_algo,
        }]
    } else {
        default_inits(&graph, n, coll_algo)
    };

    let config = SearchConfig {
        seed,
        budget,
        chains,
        threads,
        plain,
        mutate_coll: !fixed_coll,
        delta: !no_delta,
        prune: !no_prune,
        fold,
        wall_s,
        ..SearchConfig::default()
    };
    let result = Searcher::new(config).run(&graph, &cluster, &inits)?;

    if json {
        let doc = search_json(
            model.name(),
            batch,
            &cluster.name,
            n,
            seed,
            budget,
            chains,
            coll_algo,
            &result,
        );
        println!("{}", doc.to_string_pretty());
        return Ok(());
    }

    println!(
        "searched {} candidates for {} b={} on {}({} GPUs): {} chains, seed {} — {:.2}s \
         (template cache: {} misses, {} hits; delta hits {}, full compiles {}, \
         bound-pruned {})",
        result.evals,
        model.name(),
        batch,
        cluster.name,
        n,
        chains,
        seed,
        result.wall_s,
        result.cache_misses,
        result.cache_hits,
        result.delta_hits,
        result.full_compiles,
        result.bound_prunes,
    );
    let mut table = Table::new(&[
        "chain",
        "evals",
        "accepted",
        "infeasible",
        "delta",
        "full",
        "pruned",
        "best samples/s",
        "best strategy",
    ]);
    for c in &result.chains {
        table.row(vec![
            c.chain.to_string(),
            c.evals.to_string(),
            c.accepted.to_string(),
            c.infeasible.to_string(),
            c.delta_hits.to_string(),
            c.full_compiles.to_string(),
            c.bound_prunes.to_string(),
            c.best
                .as_ref()
                .map(|e| format!("{:.1}", e.throughput))
                .unwrap_or_else(|| "-".into()),
            c.best
                .as_ref()
                .map(|e| e.label.clone())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.render());
    match &result.best {
        Some(b) => {
            println!(
                "best: {}  {:.1} samples/s ({:.2} ms/step), peak mem {}",
                b.label,
                b.throughput,
                b.step_ms,
                fmt_bytes(b.peak_mem),
            );
            if b.fold_classes > 0 {
                println!(
                    "fold: {} device classes, {} devices elided",
                    b.fold_classes, b.fold_devices_folded
                );
            }
            println!("spec: {}", b.point.spec.to_json());
        }
        None => println!("no feasible strategy found within budget"),
    }
    Ok(())
}

/// Build the `proteus search --json` document from a finished
/// [`crate::runtime::SearchResult`]. Schema documented in README.md
/// ("JSON output"); deliberately free of wall-clock times and
/// template-cache counters so a seeded run is byte-reproducible — the
/// CI determinism gate diffs two runs, and the delta differential
/// harness (`tests/differential_search.rs`) diffs a delta run against a
/// `--no-delta` run through this exact function. The delta/full/prune
/// counters it does include are classification-based and equally
/// deterministic.
#[allow(clippy::too_many_arguments)]
pub fn search_json(
    model: &str,
    batch: usize,
    cluster_name: &str,
    gpus: usize,
    seed: u64,
    budget: usize,
    n_chains: usize,
    coll_algo: CollAlgo,
    result: &crate::runtime::SearchResult,
) -> Json {
    let best_json = match &result.best {
        None => Json::Null,
        Some(b) => Json::obj(vec![
            ("label", Json::Str(b.label.clone())),
            ("step_ms", Json::Num(b.step_ms)),
            ("throughput_samples_per_s", Json::Num(b.throughput)),
            ("peak_mem_bytes", Json::Num(b.peak_mem as f64)),
            ("oom", Json::Bool(b.oom)),
            ("coll_algo", Json::Str(b.point.coll_algo.name().into())),
            ("fold_classes", Json::Num(b.fold_classes as f64)),
            (
                "fold_devices_folded",
                Json::Num(b.fold_devices_folded as f64),
            ),
            ("fold_fallback", Json::Bool(b.fold_fallback)),
            ("spec", b.point.spec.to_json()),
        ]),
    };
    let chains_json: Vec<Json> = result
        .chains
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("chain", Json::Num(c.chain as f64)),
                ("seed", Json::Num(c.seed as f64)),
                ("evals", Json::Num(c.evals as f64)),
                ("accepted", Json::Num(c.accepted as f64)),
                ("infeasible", Json::Num(c.infeasible as f64)),
                ("delta_hits", Json::Num(c.delta_hits as f64)),
                ("full_compiles", Json::Num(c.full_compiles as f64)),
                ("bound_prunes", Json::Num(c.bound_prunes as f64)),
                (
                    "best_label",
                    c.best
                        .as_ref()
                        .map(|e| Json::Str(e.label.clone()))
                        .unwrap_or(Json::Null),
                ),
                (
                    "best_throughput_samples_per_s",
                    c.best
                        .as_ref()
                        .map(|e| Json::Num(e.throughput))
                        .unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("model", Json::Str(model.into())),
        ("batch", Json::Num(batch as f64)),
        ("cluster", Json::Str(cluster_name.into())),
        ("gpus", Json::Num(gpus as f64)),
        ("seed", Json::Num(seed as f64)),
        ("budget", Json::Num(budget as f64)),
        ("n_chains", Json::Num(n_chains as f64)),
        ("coll_algo", Json::Str(coll_algo.name().into())),
        ("evals", Json::Num(result.evals as f64)),
        ("delta_hits", Json::Num(result.delta_hits as f64)),
        ("full_compiles", Json::Num(result.full_compiles as f64)),
        ("bound_prunes", Json::Num(result.bound_prunes as f64)),
        ("best", best_json),
        ("chains", Json::Arr(chains_json)),
    ])
}

/// Rank an exhaustive strategy grid with the parallel [`SweepRunner`].
fn cmd_sweep(args: &Args) -> Result<()> {
    use crate::runtime::{candidate_grid_with_schedules, dedupe_specs, Scenario, SweepRunner};

    let model = args.get_or("model", "gpt2");
    let model = ModelKind::parse(&model)
        .ok_or_else(|| Error::Config(format!("unknown model '{model}'")))?;
    let batch = args.get_usize("batch", 64)?;
    let preset = args.get_or("preset", "HC2");
    let preset = Preset::parse(&preset)
        .ok_or_else(|| Error::Config(format!("unknown preset '{preset}'")))?;
    let nodes = args.get_usize("nodes", 2)?;
    let threads = args.get_usize("threads", 0)?;
    let top = args.get_usize("top", 10)?;
    let plain = args.flag("plain");
    let truth = args.flag("truth");
    let json = args.flag("json");
    let fold = args.flag("fold");
    let coll_algo = parse_coll_algo(args)?;
    let schedules = parse_schedules(&args.get_or("schedules", "1f1b"))?;
    let artifact = args.get_or("artifacts", DEFAULT_ARTIFACT);
    // Validates the overrides up front; the runner re-applies them to
    // each scenario's cluster.
    let (nics, oversub) = fabric_overrides(args)?;
    let cluster = build_cluster(args, preset, nodes)?;
    args.reject_unknown()?;

    let n = cluster.num_devices();
    let graph = model.build(batch);
    let grid = candidate_grid_with_schedules(n, batch, &schedules);
    let n_grid = grid.len();
    // Commuting factorizations (e.g. a no-op ZeRO toggle) resolve to
    // identical strategies; simulate each resolved strategy once.
    let specs = dedupe_specs(&graph, grid);
    let n_dupes = n_grid - specs.len();
    let scenarios: Vec<Scenario> = specs
        .into_iter()
        .map(|spec| Scenario {
            model,
            batch,
            preset,
            nodes,
            spec,
        })
        .collect();
    let runner = SweepRunner::new()
        .with_threads(threads)
        .plain(plain)
        .coll_algo(coll_algo)
        .fold(fold)
        .fabric(nics, oversub);
    let n_threads = runner.effective_threads(scenarios.len());
    let t0 = std::time::Instant::now();
    let outcomes = runner.run(&scenarios);
    let wall = t0.elapsed();
    let ranked = SweepRunner::rank(&outcomes);
    let oom = outcomes.iter().filter(|o| o.oom).count();
    let feasible = ranked.iter().filter(|o| !o.oom).count();
    let failed = outcomes.iter().filter(|o| o.report.is_err()).count();
    // Emulator validation of the top candidates, shared by both output
    // modes: (label, truth step_ms, truth samples/s, HTAE err %).
    // Only feasible candidates are validated — an OOM candidate cannot
    // run, so emulating it would report an error for a configuration
    // the ranking already marks unusable.
    let truth_rows: Vec<(String, f64, f64, f64)> = if truth {
        let est = OpEstimator::best_available(&cluster, &artifact);
        let mut rows = Vec::new();
        for o in ranked.iter().filter(|o| !o.oom).take(3) {
            let tree = build_strategy(&graph, o.scenario.spec)?;
            let eg = crate::compiler::compile(&graph, &tree, &cluster)?;
            let emu_config = EmulatorConfig {
                coll_algo,
                ..EmulatorConfig::default()
            };
            let t = Emulator::with_config(&cluster, &est, emu_config).simulate(&eg)?;
            let pred = o.report.as_ref().unwrap();
            rows.push((
                o.scenario.spec.label(),
                t.step_ms,
                t.throughput,
                rel_err_pct(pred.step_ms, t.step_ms),
            ));
        }
        rows
    } else {
        Vec::new()
    };
    if json {
        // Schema documented in README.md ("JSON output").
        let results: Vec<Json> = ranked
            .iter()
            .take(top)
            .enumerate()
            .map(|(i, o)| {
                let r = o.report.as_ref().unwrap();
                Json::obj(vec![
                    ("rank", Json::Num((i + 1) as f64)),
                    ("strategy", Json::Str(o.scenario.spec.label())),
                    ("schedule", Json::Str(o.scenario.spec.schedule.name())),
                    ("step_ms", Json::Num(r.step_ms)),
                    ("throughput_samples_per_s", Json::Num(r.throughput)),
                    (
                        "peak_mem_bytes",
                        Json::Num(r.peak_mem.iter().copied().max().unwrap_or(0) as f64),
                    ),
                    // Infeasible candidates rank below every feasible
                    // one but stay visible (with their would-be speed).
                    ("oom", Json::Bool(o.oom)),
                    ("fold_classes", Json::Num(o.fold_classes as f64)),
                    (
                        "fold_devices_folded",
                        Json::Num(o.fold_devices_folded as f64),
                    ),
                    ("fold_fallback", Json::Bool(o.fold_fallback)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("model", Json::Str(model.name().into())),
            ("batch", Json::Num(batch as f64)),
            ("cluster", Json::Str(cluster.name.clone())),
            ("gpus", Json::Num(n as f64)),
            (
                "schedules",
                Json::Arr(schedules.iter().map(|s| Json::Str(s.name())).collect()),
            ),
            ("coll_algo", Json::Str(coll_algo.name().into())),
            ("grid", Json::Num(n_grid as f64)),
            ("deduped", Json::Num(n_dupes as f64)),
            ("swept", Json::Num(outcomes.len() as f64)),
            ("viable", Json::Num(feasible as f64)),
            ("oom", Json::Num(oom as f64)),
            ("invalid", Json::Num(failed as f64)),
            ("fold", Json::Bool(fold)),
            ("wall_s", Json::Num(wall.as_secs_f64())),
            ("threads", Json::Num(n_threads as f64)),
            ("results", Json::Arr(results)),
        ];
        if truth {
            fields.push((
                "truth",
                Json::Arr(
                    truth_rows
                        .iter()
                        .map(|(label, step_ms, tput, err)| {
                            Json::obj(vec![
                                ("strategy", Json::Str(label.clone())),
                                ("step_ms", Json::Num(*step_ms)),
                                ("throughput_samples_per_s", Json::Num(*tput)),
                                ("err_pct", Json::Num(*err)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        println!("{}", Json::obj(fields).to_string_pretty());
        return Ok(());
    }
    println!(
        "swept {} strategies for {} b={} on {}({} GPUs): {} viable, {} OOM, {} invalid, \
         {} duplicates dropped — {:.2?} on {} threads",
        outcomes.len(),
        model.name(),
        batch,
        cluster.name,
        n,
        feasible,
        oom,
        failed,
        n_dupes,
        wall,
        n_threads,
    );
    let mut table = Table::new(&["rank", "strategy", "step_ms", "samples/s", "oom"]);
    for (i, o) in ranked.iter().take(top).enumerate() {
        let r = o.report.as_ref().unwrap();
        table.row(vec![
            (i + 1).to_string(),
            o.scenario.spec.label(),
            format!("{:.2}", r.step_ms),
            format!("{:.1}", r.throughput),
            if o.oom { "OOM".into() } else { "-".to_string() },
        ]);
    }
    print!("{}", table.render());
    if fold {
        let folded = outcomes.iter().filter(|o| o.fold_classes > 0).count();
        let fell_back = outcomes.iter().filter(|o| o.fold_fallback).count();
        println!(
            "fold: {folded} candidates folded, {fell_back} fell back to the unfolded graph"
        );
    }
    for (label, step_ms, tput, err) in &truth_rows {
        println!("truth {label}: {step_ms:.2} ms ({tput:.1} samples/s), HTAE error {err:.2}%");
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let out = args.get("out").map(|s| s.to_string());
    args.reject_unknown()?;
    let mut pairs = Vec::new();
    let mut table = Table::new(&["preset", "device", "gamma"]);
    for &p in Preset::all() {
        let c = Cluster::preset(p, 1);
        let g = calibrate::calibrate_gamma(&c)?;
        table.row(vec![
            p.name().into(),
            c.device.name.clone(),
            format!("{g:.4}"),
        ]);
        pairs.push((p.name(), Json::Num(g)));
    }
    print!("{}", table.render());
    if let Some(path) = out {
        let doc = Json::obj(pairs.iter().map(|(k, v)| (*k, v.clone())).collect());
        std::fs::write(&path, doc.to_string_pretty())?;
        println!("written to {path}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2");
    let model = ModelKind::parse(&model)
        .ok_or_else(|| Error::Config(format!("unknown model '{model}'")))?;
    let batch = args.get_usize("batch", 8)?;
    args.reject_unknown()?;
    let g = model.build(batch);
    println!("model={} batch={batch}", model.name());
    println!("layers={} tensors={}", g.layers.len(), g.tensors.len());
    println!("params={:.1}M", g.num_params() as f64 / 1e6);
    println!(
        "fwd_flops={:.2} GFLOP/step",
        g.total_fwd_flops() as f64 / 1e9
    );
    Ok(())
}

fn cmd_bench_cost(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 65536)?;
    let path = args.get_or("artifacts", DEFAULT_ARTIFACT);
    args.reject_unknown()?;
    let cluster = Cluster::preset(Preset::HC2, 4);
    let g = ModelKind::Gpt2.build(64);
    let tree = build_strategy(&g, StrategySpec::data_parallel(8))?;
    let eg = crate::compiler::compile(&g, &tree, &cluster)?;
    let analytical = OpEstimator::analytical(&cluster);
    let mut matrix = analytical.feature_matrix(&eg);
    while matrix.len() < rows {
        matrix.extend_from_within(0..matrix.len().min(rows - matrix.len()));
    }
    matrix.truncate(rows);
    let t0 = std::time::Instant::now();
    let a = analytical.eval_rows(&matrix)?;
    let t_analytical = t0.elapsed();
    println!(
        "analytical: {rows} rows in {:?} ({:.1} Mrows/s)",
        t_analytical,
        rows as f64 / t_analytical.as_secs_f64() / 1e6
    );
    if std::path::Path::new(&path).exists() {
        let pjrt = OpEstimator::pjrt(&cluster, &path)?;
        let t1 = std::time::Instant::now();
        let b = pjrt.eval_rows(&matrix)?;
        let t_pjrt = t1.elapsed();
        println!(
            "pjrt:       {rows} rows in {:?} ({:.1} Mrows/s)",
            t_pjrt,
            rows as f64 / t_pjrt.as_secs_f64() / 1e6
        );
        let max_rel = a
            .iter()
            .zip(&b)
            .map(|(x, y)| ((x - y).abs() / x.abs().max(1.0)) as f64)
            .fold(0.0f64, f64::max);
        println!("max backend divergence: {max_rel:.2e}");
    } else {
        println!("pjrt:       skipped ({path} missing; run `make artifacts`)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn workload_parsing_defaults() {
        let a = parse("simulate --model vgg19 --batch 32 --dp 4");
        let (m, b, c, s) = parse_workload(&a).unwrap();
        assert_eq!(m, ModelKind::Vgg19);
        assert_eq!(b, 32);
        assert_eq!(c.name, "HC1");
        assert_eq!(s.dp, 4);
        assert_eq!(s.mp, 1);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let a = parse("simulate --model resnet152");
        assert!(parse_workload(&a).is_err());
    }

    #[test]
    fn spec_from_json_reads_all_fields() {
        let j = Json::parse(
            r#"{"dp":2,"mp":4,"pp":2,"micro":8,"zero":true,"recompute":true,"emb_shard":true}"#,
        )
        .unwrap();
        let s = spec_from_json(&j).unwrap();
        assert_eq!((s.dp, s.mp, s.pp, s.n_micro_batch), (2, 4, 2, 8));
        assert!(s.zero && s.recompute && s.shard_embeddings);
    }

    #[test]
    fn schedule_flags_parse() {
        let a = parse("simulate --pp 2 --micro 4 --schedule gpipe");
        let (_, _, _, s) = parse_workload(&a).unwrap();
        assert_eq!(s.schedule, PipelineSchedule::GpipeFillDrain);
        let a = parse("simulate --pp 2 --micro 4 --schedule interleaved --vstages 3");
        let (_, _, _, s) = parse_workload(&a).unwrap();
        assert_eq!(s.schedule, PipelineSchedule::Interleaved { v: 3 });
        let a = parse("simulate --schedule 2f2b");
        assert!(parse_workload(&a).is_err());
        // --vstages is inert without interleaved; that must fail loudly.
        let a = parse("simulate --pp 2 --vstages 4");
        assert!(parse_workload(&a).is_err());
        // Explicit 0 is rejected like interleaved:0, not silently kept.
        let a = parse("simulate --pp 2 --schedule interleaved --vstages 0");
        assert!(parse_workload(&a).is_err());
    }

    #[test]
    fn schedules_set_parses() {
        assert_eq!(parse_schedules("all").unwrap(), PipelineSchedule::all());
        assert_eq!(
            parse_schedules("gpipe,1f1b").unwrap(),
            vec![PipelineSchedule::GpipeFillDrain, PipelineSchedule::OneFOneB]
        );
        assert!(parse_schedules("bogus").is_err());
    }

    #[test]
    fn help_flag_short_circuits() {
        let a = parse("simulate --help");
        run(&a).unwrap();
    }

    #[test]
    fn unknown_command_fails() {
        let a = parse("frobnicate");
        assert!(run(&a).is_err());
    }

    #[test]
    fn info_command_runs() {
        let a = parse("info --model resnet50 --batch 8");
        run(&a).unwrap();
    }

    #[test]
    fn coll_algo_flag_parses_and_runs() {
        for algo in ["ring", "tree", "hier", "auto", "mono"] {
            let a = parse(&format!(
                "simulate --model vgg19 --batch 16 --preset HC2 --nodes 2 --dp 16 \
                 --coll-algo {algo} --json"
            ));
            run(&a).unwrap();
        }
        let a = parse("simulate --model vgg19 --batch 8 --coll-algo bogus");
        assert!(run(&a).is_err());
    }

    #[test]
    fn compile_stats_flag_runs_in_both_output_modes() {
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 4 \
             --compile-stats",
        );
        run(&a).unwrap();
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 4 \
             --compile-stats --json",
        );
        run(&a).unwrap();
    }

    #[test]
    fn sweep_command_runs() {
        let a = parse("sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2");
        run(&a).unwrap();
    }

    #[test]
    fn sweep_command_enumerates_all_schedules_in_one_invocation() {
        let a = parse(
            "sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2 \
             --schedules all --json",
        );
        run(&a).unwrap();
    }

    #[test]
    fn search_command_runs_in_both_output_modes() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 8 --chains 2 \
             --seed 3",
        );
        run(&a).unwrap();
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 8 --chains 2 \
             --seed 3 --json",
        );
        run(&a).unwrap();
    }

    /// `--resume` must validate the loaded spec against the *current*
    /// `--preset/--nodes` device budget. Before the fix the mismatch
    /// only surfaced as a per-chain compile error deep inside the
    /// search (every chain silently infeasible); this pins the clean
    /// up-front `Config` error.
    #[test]
    fn search_resume_validates_device_budget() {
        use crate::strategy::NonUniformSpec;
        let g = ModelKind::Vgg19.build(16);
        // A best spec from a 32-GPU run: dp=4 × mp=8.
        let spec = NonUniformSpec::single_stage(&g, 4, 8);
        assert_eq!(spec.n_devices(), 32);
        let doc = Json::obj(vec![(
            "best",
            Json::obj(vec![
                ("label", Json::Str(spec.label())),
                ("coll_algo", Json::Str("auto".into())),
                ("spec", spec.to_json()),
            ]),
        )]);
        let path = std::env::temp_dir().join(format!(
            "proteus_resume_budget_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        // Resumed onto a single HC1 node — far fewer than 32 devices.
        let a = parse(&format!(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 4 --chains 1 \
             --resume {}",
            path.display()
        ));
        let err = run(&a).unwrap_err().to_string();
        std::fs::remove_file(&path).unwrap();
        assert!(err.contains("devices"), "unexpected error: {err}");
        assert!(err.contains("32"), "unexpected error: {err}");
    }

    #[test]
    fn search_no_delta_and_no_prune_flags_run() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --seed 3 --no-delta --no-prune --json",
        );
        run(&a).unwrap();
    }

    #[test]
    fn search_accepts_init_label_and_rejects_garbage() {
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --init 8x1x1(1)",
        );
        run(&a).unwrap();
        let a = parse("search --model vgg19 --batch 16 --init not-a-spec --budget 4");
        assert!(run(&a).is_err());
        let a = parse("search --model vgg19 --batch 16 --resume /nonexistent/search.json");
        assert!(run(&a).is_err());
    }

    /// `--fold` is accepted by all three strategy commands and runs end
    /// to end (the fold/unfold *equivalence* is pinned by
    /// `tests/differential_fold.rs` and the runtime unit tests; this is
    /// the CLI surface smoke).
    #[test]
    fn fold_flag_runs_across_commands() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC2 --nodes 2 --dp 16 --fold \
             --compile-stats --json",
        );
        run(&a).unwrap();
        let a = parse(
            "sweep --model vgg19 --batch 16 --preset HC1 --nodes 1 --top 3 --threads 2 \
             --fold --json",
        );
        run(&a).unwrap();
        let a = parse(
            "search --model vgg19 --batch 16 --preset HC1 --nodes 1 --budget 6 --chains 1 \
             --seed 3 --fold --json",
        );
        run(&a).unwrap();
    }

    /// `--nics`/`--oversub` rebuild the preset fabric through the same
    /// validation as a hand-written [`crate::cluster::ClusterSpec`].
    #[test]
    fn fabric_overrides_parse_and_validate() {
        let a = parse(
            "simulate --model vgg19 --batch 16 --preset HC4 --nodes 2 --dp 16 \
             --nics 4 --oversub 2.0 --json",
        );
        run(&a).unwrap();
        // More NICs than GPU ports on the node.
        let a = parse("simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --nics 64");
        assert!(run(&a).is_err());
        // Oversubscription below 1.0 would mint bandwidth.
        let a = parse("simulate --model vgg19 --batch 16 --preset HC1 --nodes 1 --oversub 0.5");
        assert!(run(&a).is_err());
        // Non-numeric values fail loudly.
        let a = parse("simulate --model vgg19 --batch 16 --nics many");
        assert!(run(&a).is_err());
        let a = parse("simulate --model vgg19 --batch 16 --oversub wide");
        assert!(run(&a).is_err());
    }

    #[test]
    fn simulate_json_with_explicit_schedule_runs() {
        let a = parse(
            "simulate --model gpt2 --batch 8 --preset HC1 --nodes 1 --pp 2 --micro 2 \
             --schedule gpipe --json",
        );
        run(&a).unwrap();
    }
}
