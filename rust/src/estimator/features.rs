//! Feature schema shared between the Rust analytical cost mirror and the
//! AOT Pallas cost kernel (L1).
//!
//! Every task becomes one row of `FEATURES` f32 values; the kernel (and
//! the bit-faithful Rust mirror, [`cost_ns`]) evaluates
//!
//! ```text
//! comp:  cost_ns = launch_ns + max(flops/eff_flops, bytes/eff_bw) · 1e9
//! comm:  cost_ns = steps · alpha_ns + traffic/bus_bw · 1e9
//! blended: cost = (1-is_comm)·comp + is_comm·comm
//! ```
//!
//! Topology-dependent quantities (`bus_bw`, `alpha`, `traffic`, `steps`)
//! are computed on the Rust side from the cluster model; the kernel is
//! pure elementwise arithmetic over the row — which is what makes it a
//! clean Pallas tile kernel. Keep in sync with
//! `python/compile/kernels/costmodel.py` and `ref.py`.

use crate::cluster::Cluster;
use crate::compiler::{CollectiveKind, CommTask, CompTask};

/// Row width of the feature matrix (padded; the kernel reads the first
/// [`USED_FEATURES`]).
pub const FEATURES: usize = 16;
/// Populated feature slots.
pub const USED_FEATURES: usize = 10;

/// Feature slot indices.
pub mod slot {
    /// 1.0 for communication rows, 0.0 for computation rows.
    pub const IS_COMM: usize = 0;
    /// Computation FLOPs.
    pub const FLOPS: usize = 1;
    /// Computation bytes touched (read + written).
    pub const BYTES: usize = 2;
    /// Effective FLOP/s (device peak × kind efficiency).
    pub const EFF_FLOPS: usize = 3;
    /// Effective bytes/s (device bandwidth × kind efficiency).
    pub const EFF_BW: usize = 4;
    /// Launch overhead in ns.
    pub const LAUNCH_NS: usize = 5;
    /// Collective latency steps.
    pub const STEPS: usize = 6;
    /// Per-step latency α in ns.
    pub const ALPHA_NS: usize = 7;
    /// Bus traffic bytes (collective-algorithm adjusted).
    pub const TRAFFIC: usize = 8;
    /// Bus bandwidth bytes/s.
    pub const BUS_BW: usize = 9;
}

/// One feature row.
pub type Row = [f32; FEATURES];

/// Build the feature row of a computation task.
pub fn comp_row(t: &CompTask, cluster: &Cluster) -> Row {
    let dev = &cluster.device;
    let mut r = [0f32; FEATURES];
    r[slot::IS_COMM] = 0.0;
    r[slot::FLOPS] = t.flops as f32;
    r[slot::BYTES] = (t.bytes_read + t.bytes_written) as f32;
    r[slot::EFF_FLOPS] = (dev.peak_flops * t.op.flops_efficiency()) as f32;
    r[slot::EFF_BW] = (dev.mem_bandwidth * t.op.mem_efficiency()) as f32;
    r[slot::LAUNCH_NS] = t.op.launch_overhead_ns() as f32;
    r
}

/// Collective algorithm profile: `(steps, traffic_factor)` such that
/// bus traffic = `traffic_factor × bytes` and latency = `steps × α`.
/// Ring algorithms for the reduction collectives, binomial tree for
/// broadcast (the standard NCCL-era cost model).
pub fn collective_profile(kind: CollectiveKind, n: usize) -> (f64, f64) {
    let n = n.max(1) as f64;
    match kind {
        CollectiveKind::AllReduce => (2.0 * (n - 1.0), 2.0 * (n - 1.0) / n),
        CollectiveKind::AllGather | CollectiveKind::ReduceScatter => {
            (n - 1.0, (n - 1.0) / n)
        }
        CollectiveKind::AllToAll => (n - 1.0, (n - 1.0) / n),
        CollectiveKind::Broadcast => (n.log2().ceil().max(1.0), 1.0),
        CollectiveKind::P2p => (1.0, 1.0),
    }
}

/// Build the feature row of a communication task.
pub fn comm_row(t: &CommTask, cluster: &Cluster) -> Row {
    let mut r = [0f32; FEATURES];
    r[slot::IS_COMM] = 1.0;
    let n = t.group.len();
    let (steps, factor) = collective_profile(t.kind, n);
    let (bus_bw, alpha_ps) = match t.kind {
        CollectiveKind::P2p => {
            let (a, b) = (t.group[0], t.group[1]);
            (cluster.pair_bandwidth(a, b), cluster.pair_latency(a, b))
        }
        _ => (
            cluster.ring_bus_bandwidth(&t.group),
            cluster.ring_latency(&t.group),
        ),
    };
    r[slot::STEPS] = steps as f32;
    r[slot::ALPHA_NS] = (alpha_ps as f64 / 1e3) as f32;
    r[slot::TRAFFIC] = (t.bytes as f64 * factor) as f32;
    r[slot::BUS_BW] = if bus_bw.is_finite() {
        bus_bw as f32
    } else {
        f32::MAX
    };
    r
}

/// The cost function over one row, in nanoseconds. This is the exact
/// arithmetic the Pallas kernel performs (f32), so the PJRT backend and
/// this mirror agree to float rounding.
pub fn cost_ns(r: &Row) -> f32 {
    let comp = r[slot::LAUNCH_NS]
        + (r[slot::FLOPS] / r[slot::EFF_FLOPS].max(1.0))
            .max(r[slot::BYTES] / r[slot::EFF_BW].max(1.0))
            * 1e9;
    let comm = r[slot::STEPS] * r[slot::ALPHA_NS]
        + r[slot::TRAFFIC] / r[slot::BUS_BW].max(1.0) * 1e9;
    (1.0 - r[slot::IS_COMM]) * comp + r[slot::IS_COMM] * comm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Preset;
    use crate::graph::OpKind;

    fn cluster() -> Cluster {
        Cluster::preset(Preset::HC2, 2)
    }

    #[test]
    fn comp_row_roofline_picks_the_max() {
        let c = cluster();
        // Huge flops, tiny bytes → compute bound.
        let t = CompTask {
            device: 0,
            op: OpKind::Linear,
            flops: 1e12,
            bytes_read: 1e3,
            bytes_written: 1e3,
        };
        let r = comp_row(&t, &c);
        let ns = cost_ns(&r);
        let expect = 5_000.0 + 1e12 / (15.7e12 * 0.62) * 1e9;
        assert!((ns - expect as f32).abs() / (expect as f32) < 1e-3);
    }

    #[test]
    fn bandwidth_bound_op_ignores_flops() {
        let c = cluster();
        let t = CompTask {
            device: 0,
            op: OpKind::Elementwise,
            flops: 1.0,
            bytes_read: 1e9,
            bytes_written: 1e9,
        };
        let ns = cost_ns(&comp_row(&t, &c));
        let expect = 5_000.0 + 2e9 / (900e9 * 0.82) * 1e9;
        assert!((ns - expect as f32).abs() / (expect as f32) < 1e-3);
    }

    /// Satellite coverage: step counts and traffic factors across every
    /// `CollectiveKind`, including degenerate 1-rank groups.
    #[test]
    fn collective_profile_steps_across_all_kinds() {
        for n in [2usize, 4, 8, 16] {
            let nf = n as f64;
            let (s, f) = collective_profile(CollectiveKind::AllReduce, n);
            assert_eq!(s, 2.0 * (nf - 1.0));
            assert!((f - 2.0 * (nf - 1.0) / nf).abs() < 1e-12);
            for kind in [CollectiveKind::AllGather, CollectiveKind::ReduceScatter] {
                let (s, f) = collective_profile(kind, n);
                assert_eq!(s, nf - 1.0, "{kind:?}");
                assert!((f - (nf - 1.0) / nf).abs() < 1e-12, "{kind:?}");
            }
            let (s, f) = collective_profile(CollectiveKind::AllToAll, n);
            assert_eq!(s, nf - 1.0);
            assert!((f - (nf - 1.0) / nf).abs() < 1e-12);
            let (s, f) = collective_profile(CollectiveKind::Broadcast, n);
            assert_eq!(s, nf.log2().ceil().max(1.0));
            assert_eq!(f, 1.0);
            let (s, f) = collective_profile(CollectiveKind::P2p, n);
            assert_eq!((s, f), (1.0, 1.0));
        }
    }

    /// Degenerate 1-rank groups: the reduction collectives are free
    /// (zero steps, zero traffic); broadcast/p2p keep one launch step
    /// but move nothing beyond their own buffer.
    #[test]
    fn collective_profile_one_rank_groups() {
        let (s, f) = collective_profile(CollectiveKind::AllReduce, 1);
        assert_eq!((s, f), (0.0, 0.0));
        for kind in [
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllToAll,
        ] {
            let (s, f) = collective_profile(kind, 1);
            assert_eq!((s, f), (0.0, 0.0), "{kind:?}");
        }
        let (s, f) = collective_profile(CollectiveKind::Broadcast, 1);
        assert_eq!((s, f), (1.0, 1.0));
        let (s, f) = collective_profile(CollectiveKind::P2p, 1);
        assert_eq!((s, f), (1.0, 1.0));
        // n = 0 clamps to 1 rather than producing NaNs.
        let (s, f) = collective_profile(CollectiveKind::AllReduce, 0);
        assert_eq!((s, f), (0.0, 0.0));
    }

    #[test]
    fn allreduce_traffic_factor() {
        let (steps, f) = collective_profile(CollectiveKind::AllReduce, 4);
        assert_eq!(steps, 6.0);
        assert!((f - 1.5).abs() < 1e-12);
        let (_, f2) = collective_profile(CollectiveKind::AllGather, 4);
        assert!((f2 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn intra_node_comm_cheaper_than_cross_node() {
        let c = cluster();
        let mk = |group: Vec<usize>| CommTask {
            kind: CollectiveKind::AllReduce,
            group,
            bytes: 1 << 24,
            class: crate::compiler::CommClass::Gradient,
        };
        let intra = cost_ns(&comm_row(&mk((0..8).collect()), &c));
        let cross = cost_ns(&comm_row(&mk(vec![0, 8]), &c));
        assert!(cross > intra, "{cross} vs {intra}");
    }

    #[test]
    fn singleton_group_comm_is_latency_only() {
        let c = cluster();
        let t = CommTask {
            kind: CollectiveKind::AllReduce,
            group: vec![3],
            bytes: 1 << 20,
            class: crate::compiler::CommClass::Gradient,
        };
        let r = comm_row(&t, &c);
        // traffic factor 0 for n=1
        assert_eq!(r[slot::TRAFFIC], 0.0);
    }

    #[test]
    fn p2p_uses_pair_path() {
        let c = cluster();
        let t = CommTask {
            kind: CollectiveKind::P2p,
            group: vec![0, 9],
            bytes: 1 << 24,
            class: crate::compiler::CommClass::Feature,
        };
        let r = comm_row(&t, &c);
        // Cross-node: NIC 12 GB/s is the bottleneck.
        assert!((r[slot::BUS_BW] - 12e9 as f32).abs() / 12e9 < 1e-3);
    }
}
