//! Op estimator (paper §VII): per-operator base costs.
//!
//! The estimator assigns every task of an execution graph its
//! *contention-free* cost: a roofline model for computation shards
//! (device peak × per-kind profiled efficiency) and an α-β model with
//! collective-algorithm corrections for communication, using the
//! cluster's detailed topology for group bandwidth (the paper's
//! NCCL-topo-detection analogue).
//!
//! Two interchangeable backends evaluate the (identical) cost
//! arithmetic:
//!
//! - [`CostBackend::Analytical`] — pure Rust mirror, used by unit tests
//!   and as a fallback;
//! - [`CostBackend::Pjrt`] — the AOT-compiled JAX/Pallas kernel
//!   (`artifacts/costmodel.hlo.txt`) executed through the PJRT C API;
//!   this is the production path exercising the three-layer stack.
//!
//! Feature extraction (topology lookups) is Rust either way; the kernel
//! is pure elementwise math over the feature matrix — see
//! [`features`].

pub mod features;

pub use features::{comm_row, comp_row, cost_ns, Row, FEATURES};

use crate::cluster::Cluster;
use crate::compiler::{ExecGraph, TaskRef};
use crate::runtime::CostKernel;
use crate::util::time::Ps;
use crate::Result;

/// Cost evaluation backend.
pub enum CostBackend {
    /// Pure-Rust mirror of the kernel arithmetic.
    Analytical,
    /// AOT XLA kernel via PJRT.
    Pjrt(CostKernel),
}

/// The op estimator: topology-aware feature extraction + cost backend.
pub struct OpEstimator<'c> {
    cluster: &'c Cluster,
    backend: CostBackend,
}

impl<'c> OpEstimator<'c> {
    /// Estimator with the analytical backend.
    pub fn analytical(cluster: &'c Cluster) -> Self {
        OpEstimator {
            cluster,
            backend: CostBackend::Analytical,
        }
    }

    /// Estimator with the PJRT backend, loading the AOT artifact at
    /// `path` (e.g. `artifacts/costmodel.hlo.txt`).
    pub fn pjrt(cluster: &'c Cluster, path: &str) -> Result<Self> {
        Ok(OpEstimator {
            cluster,
            backend: CostBackend::Pjrt(CostKernel::load(path)?),
        })
    }

    /// Estimator with the PJRT backend if the artifact exists, falling
    /// back to the analytical mirror (used by examples so they run
    /// before `make artifacts`).
    pub fn best_available(cluster: &'c Cluster, path: &str) -> Self {
        match std::path::Path::new(path).exists() {
            true => Self::pjrt(cluster, path).unwrap_or_else(|e| {
                eprintln!("warning: PJRT cost kernel unavailable ({e}); using analytical backend");
                Self::analytical(cluster)
            }),
            false => Self::analytical(cluster),
        }
    }

    /// Whether the PJRT backend is active.
    pub fn is_pjrt(&self) -> bool {
        matches!(self.backend, CostBackend::Pjrt(_))
    }

    /// The cluster this estimator models.
    pub fn cluster(&self) -> &Cluster {
        self.cluster
    }

    /// Build the feature matrix for a whole execution graph.
    pub fn feature_matrix(&self, eg: &ExecGraph) -> Vec<Row> {
        (0..eg.n_tasks())
            .map(|i| match eg.kind(i) {
                TaskRef::Comp(c) => comp_row(c, self.cluster),
                TaskRef::Comm(c) => comm_row(c, self.cluster),
            })
            .collect()
    }

    /// Estimate the contention-free cost of every task, in picoseconds.
    pub fn estimate_all(&self, eg: &ExecGraph) -> Result<Vec<Ps>> {
        let rows = self.feature_matrix(eg);
        let ns = self.eval_rows(&rows)?;
        Ok(ns.iter().map(|&v| ns_to_ps(v)).collect())
    }

    /// Evaluate cost rows through the active backend (ns per row).
    pub fn eval_rows(&self, rows: &[Row]) -> Result<Vec<f32>> {
        match &self.backend {
            CostBackend::Analytical => Ok(rows.iter().map(cost_ns).collect()),
            CostBackend::Pjrt(k) => k.eval(rows),
        }
    }
}

fn ns_to_ps(ns: f32) -> Ps {
    if !ns.is_finite() || ns <= 0.0 {
        return 0;
    }
    (ns as f64 * 1e3).round() as Ps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Preset;
    use crate::graph::{DType, GraphBuilder};
    use crate::strategy::{build_strategy, StrategySpec};

    fn small_dp_graph() -> (crate::graph::Graph, Cluster) {
        let mut b = GraphBuilder::new("m", 8);
        let x = b.input("x", &[8, 256], DType::F32);
        let h = b.linear("fc1", x, 256, 1024);
        let h = b.relu("act", h);
        let h = b.linear("fc2", h, 1024, 256);
        let _ = b.loss("loss", h);
        (b.finish(), Cluster::preset(Preset::HC1, 1))
    }

    #[test]
    fn analytical_costs_are_positive_and_finite() {
        let (g, c) = small_dp_graph();
        let tree = build_strategy(&g, StrategySpec::data_parallel(4)).unwrap();
        let eg = crate::compiler::compile(&g, &tree, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        let costs = est.estimate_all(&eg).unwrap();
        assert_eq!(costs.len(), eg.n_tasks());
        for (i, &ps) in costs.iter().enumerate() {
            assert!(ps > 0, "task {i} has zero cost: {:?}", eg.kind(i));
            assert!(ps < crate::util::time::SEC, "task {i} absurdly slow");
        }
    }

    #[test]
    fn bigger_shards_cost_more() {
        let (g, c) = small_dp_graph();
        let t2 = build_strategy(&g, StrategySpec::data_parallel(2)).unwrap();
        let t8 = build_strategy(&g, StrategySpec::data_parallel(8)).unwrap();
        let eg2 = crate::compiler::compile(&g, &t2, &c).unwrap();
        let eg8 = crate::compiler::compile(&g, &t8, &c).unwrap();
        let est = OpEstimator::analytical(&c);
        // Compare the fc1 fwd task cost: dp=2 shard is 4× the dp=8 shard.
        let cost_of_fc1 = |eg: &ExecGraph, costs: &[Ps]| -> Ps {
            eg.iter()
                .zip(costs)
                .find(|(t, _)| {
                    t.layer == Some(0) && t.phase == crate::compiler::Phase::Fwd && !t.is_comm()
                })
                .map(|(_, &c)| c)
                .unwrap()
        };
        let c2 = cost_of_fc1(&eg2, &est.estimate_all(&eg2).unwrap());
        let c8 = cost_of_fc1(&eg8, &est.estimate_all(&eg8).unwrap());
        assert!(c2 > c8, "{c2} vs {c8}");
    }

    #[test]
    fn best_available_falls_back_without_artifact() {
        let c = Cluster::preset(Preset::HC1, 1);
        let est = OpEstimator::best_available(&c, "/nonexistent/costmodel.hlo.txt");
        assert!(!est.is_pjrt());
    }
}
