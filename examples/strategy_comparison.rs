//! Strategy comparison (the paper's Table V workflow): sweep GPT-2
//! across `DP × MP × PP (n_micro)` strategies on HC1 and HC2, predict
//! each throughput with HTAE, validate against the emulator, and check
//! that the predicted *ranking* of strategies matches the true ranking —
//! order preservation is what makes a simulator usable for strategy
//! search.
//!
//! ```bash
//! cargo run --release --example strategy_comparison
//! ```

use proteus::executor::calibrate;
use proteus::prelude::*;
use proteus::util::table::Table;

fn sweep(
    preset: Preset,
    nodes: usize,
    batch: usize,
    specs: &[StrategySpec],
) -> proteus::Result<()> {
    let cluster = Cluster::preset(preset, nodes);
    let model = ModelKind::Gpt2.build(batch);
    let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
    let config = HtaeConfig {
        gamma: calibrate::default_gamma(&cluster),
        ..HtaeConfig::default()
    };

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for &spec in specs {
        let tree = build_strategy(&model, spec)?;
        let eg = compile(&model, &tree, &cluster)?;
        let pred = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
        let truth = Emulator::new(&cluster, &est).simulate(&eg)?;
        rows.push((spec.label(), pred.throughput, truth.throughput));
    }

    // Ranks: 1 = fastest.
    let rank = |xs: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
        let mut r = vec![0; xs.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos + 1;
        }
        r
    };
    let pred_rank = rank(&rows.iter().map(|r| r.1).collect::<Vec<_>>());
    let true_rank = rank(&rows.iter().map(|r| r.2).collect::<Vec<_>>());

    let mut table = Table::new(&["strategy", "pred sps", "true sps", "err%", "rank (true/pred)"]);
    let mut preserved = true;
    for (i, (label, pred, truth)) in rows.iter().enumerate() {
        let err = (pred - truth).abs() / truth * 100.0;
        table.row(vec![
            label.clone(),
            format!("{pred:.1}"),
            format!("{truth:.1}"),
            format!("{err:.2}"),
            format!("{} / {}", true_rank[i], pred_rank[i]),
        ]);
        preserved &= pred_rank[i] == true_rank[i];
    }
    println!(
        "\nGPT-2 on {} ({} GPUs), global batch {batch}:",
        cluster.name,
        cluster.num_devices()
    );
    print!("{}", table.render());
    println!("rank preservation: {}", if preserved { "YES" } else { "no" });
    Ok(())
}

fn main() -> proteus::Result<()> {
    // Table V, HC1: global batch 8 on one 8-GPU node.
    sweep(
        Preset::HC1,
        1,
        8,
        &[
            StrategySpec::hybrid(8, 1, 1, 1),
            StrategySpec::hybrid(4, 2, 1, 1),
            StrategySpec::hybrid(2, 4, 1, 1),
            StrategySpec::hybrid(1, 8, 1, 1),
            StrategySpec::hybrid(2, 2, 2, 1),
            StrategySpec::hybrid(2, 2, 2, 2),
        ],
    )?;
    // Table V, HC2: global batch 64 on two 8-GPU nodes.
    sweep(
        Preset::HC2,
        2,
        64,
        &[
            StrategySpec::hybrid(16, 1, 1, 1),
            StrategySpec::hybrid(8, 2, 1, 1),
            StrategySpec::hybrid(4, 4, 1, 1),
            StrategySpec::hybrid(2, 8, 1, 1),
            StrategySpec::hybrid(8, 1, 2, 4),
            StrategySpec::hybrid(8, 1, 2, 8),
            StrategySpec::hybrid(2, 4, 2, 4),
        ],
    )?;
    Ok(())
}
