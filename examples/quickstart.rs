//! Quickstart: predict the training throughput of GPT-2 under 8-way data
//! parallelism on an HC2 (8×V100 NVLink) node, and validate the
//! prediction against the flow-level testbed emulator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use proteus::executor::calibrate;
use proteus::prelude::*;
use proteus::util::fmt_bytes;

fn main() -> proteus::Result<()> {
    // 1. Model: GPT-2 (117M) at a global batch of 32 sequences.
    let model = ModelKind::Gpt2.build(32);
    println!(
        "model: {} — {} layers, {:.1}M params",
        model.name,
        model.layers.len(),
        model.num_params() as f64 / 1e6
    );

    // 2. Cluster: one HC2 node (8×V100, NVLink, NVSwitch).
    let cluster = Cluster::preset(Preset::HC2, 1);

    // 3. Strategy: 8-way data parallelism, expressed as a strategy tree.
    let tree = build_strategy(&model, StrategySpec::data_parallel(8))?;

    // 4. Compile to a distributed execution graph.
    let exec = compile(&model, &tree, &cluster)?;
    println!(
        "execution graph: {} tasks ({} communication), {:.1} MB gradient traffic",
        exec.n_tasks(),
        exec.count(|t| t.is_comm()),
        exec.total_comm_bytes() as f64 / 1e6
    );

    // 5. Estimate per-op costs (PJRT cost kernel if built, else the
    //    analytical mirror) and simulate with HTAE.
    let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
    let config = HtaeConfig {
        gamma: calibrate::default_gamma(&cluster),
        ..HtaeConfig::default()
    };
    let report = Htae::with_config(&cluster, &est, config).simulate(&exec)?;
    println!(
        "HTAE:     step {:.2} ms, {:.1} samples/s, peak mem {}, oom={}",
        report.step_ms,
        report.throughput,
        fmt_bytes(report.peak_mem.iter().copied().max().unwrap_or(0)),
        report.oom
    );

    // 6. Ground truth: the flow-level emulator (stands in for real
    //    hardware — DESIGN.md §3).
    let truth = Emulator::new(&cluster, &est).simulate(&exec)?;
    let err = (report.step_ms - truth.step_ms).abs() / truth.step_ms * 100.0;
    println!(
        "emulator: step {:.2} ms, {:.1} samples/s  →  prediction error {:.2}%",
        truth.step_ms, truth.throughput, err
    );
    Ok(())
}
