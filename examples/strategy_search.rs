//! Automated strategy search — the paper's motivating use case (§I:
//! "performance models can be leveraged to ... compare different
//! parallelization strategies in automated parallelization systems").
//!
//! Exhaustively searches the `DP × MP × PP (n_micro) × {zero, recompute}`
//! space for GPT-2 on two HC2 nodes using Proteus as the cost model
//! (skipping OOM configs), then validates the chosen strategy against
//! the testbed emulator. Every candidate is evaluated in milliseconds —
//! the whole search costs less than profiling a single real strategy.
//!
//! ```bash
//! cargo run --release --example strategy_search
//! ```

use proteus::executor::calibrate;
use proteus::prelude::*;
use proteus::util::table::Table;

fn main() -> proteus::Result<()> {
    let batch = 64;
    let cluster = Cluster::preset(Preset::HC2, 2);
    let n = cluster.num_devices();
    let model = ModelKind::Gpt2.build(batch);
    let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
    let config = HtaeConfig {
        gamma: calibrate::default_gamma(&cluster),
        ..HtaeConfig::default()
    };

    // Candidate grid: every (dp, mp, pp) factorization of the cluster,
    // micro-batch counts for pipelines, ZeRO / recompute toggles.
    let mut candidates: Vec<StrategySpec> = Vec::new();
    for dp in [1usize, 2, 4, 8, 16] {
        for mp in [1usize, 2, 4, 8] {
            for pp in [1usize, 2] {
                if dp * mp * pp != n || batch % dp != 0 {
                    continue;
                }
                let micros: &[usize] = if pp > 1 { &[2, 4, 8] } else { &[1] };
                for &micro in micros {
                    if batch % (dp * micro) != 0 {
                        continue;
                    }
                    let base = StrategySpec::hybrid(dp, mp, pp, micro);
                    candidates.push(base);
                    candidates.push(base.with_zero());
                    if pp == 1 {
                        candidates.push(base.with_recompute());
                    }
                }
            }
        }
    }

    let t0 = std::time::Instant::now();
    let mut evaluated: Vec<(StrategySpec, SimReport)> = Vec::new();
    let mut skipped_oom = 0;
    for &spec in &candidates {
        let tree = match build_strategy(&model, spec) {
            Ok(t) => t,
            Err(_) => continue,
        };
        let eg = compile(&model, &tree, &cluster)?;
        let r = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
        if r.oom {
            skipped_oom += 1;
            continue;
        }
        evaluated.push((spec, r));
    }
    evaluated.sort_by(|a, b| b.1.throughput.partial_cmp(&a.1.throughput).unwrap());
    let search_time = t0.elapsed();

    println!(
        "searched {} candidates ({} OOM) in {:.2?} — top 5:",
        candidates.len(),
        skipped_oom,
        search_time
    );
    let mut table = Table::new(&["rank", "strategy", "pred samples/s", "pred step ms"]);
    for (i, (spec, r)) in evaluated.iter().take(5).enumerate() {
        table.row(vec![
            (i + 1).to_string(),
            spec.label(),
            format!("{:.1}", r.throughput),
            format!("{:.2}", r.step_ms),
        ]);
    }
    print!("{}", table.render());

    // Validate the winner on the testbed emulator.
    let (best_spec, best_pred) = &evaluated[0];
    let tree = build_strategy(&model, *best_spec)?;
    let eg = compile(&model, &tree, &cluster)?;
    let truth = Emulator::new(&cluster, &est).simulate(&eg)?;
    let err = (best_pred.throughput - truth.throughput).abs() / truth.throughput * 100.0;
    println!(
        "\nwinner {} validated on the emulator: predicted {:.1} vs true {:.1} samples/s ({err:.2}% error)",
        best_spec.label(),
        best_pred.throughput,
        truth.throughput
    );
    // And confirm nothing in the top-5 would actually have beaten it.
    let mut best_true = (best_spec.label(), truth.throughput);
    for (spec, _) in evaluated.iter().take(5).skip(1) {
        let tree = build_strategy(&model, *spec)?;
        let eg = compile(&model, &tree, &cluster)?;
        let t = Emulator::new(&cluster, &est).simulate(&eg)?;
        if t.throughput > best_true.1 {
            best_true = (spec.label(), t.throughput);
        }
    }
    println!(
        "true best among top-5 candidates: {} ({:.1} samples/s)",
        best_true.0, best_true.1
    );
    Ok(())
}
