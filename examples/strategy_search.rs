//! Automated strategy search — the paper's motivating use case (§I:
//! "performance models can be leveraged to ... compare different
//! parallelization strategies in automated parallelization systems").
//!
//! Generates the exhaustive `DP × MP × PP (n_micro) × {zero, recompute}`
//! grid for GPT-2 on two HC2 nodes and hands it to
//! [`proteus::runtime::SweepRunner`], which simulates every candidate in
//! parallel (deduplicating the shared model-graph build) and ranks the
//! survivors. The chosen strategy is then validated against the
//! flow-level testbed emulator. Every candidate is evaluated in
//! milliseconds — the whole search costs less than profiling a single
//! real strategy.
//!
//! ```bash
//! cargo run --release --example strategy_search
//! # equivalently: cargo run --release -- sweep --model gpt2 --batch 64 \
//! #               --preset HC2 --nodes 2 --truth
//! ```

use proteus::prelude::*;
use proteus::util::table::Table;

fn main() -> proteus::Result<()> {
    let batch = 64;
    let preset = Preset::HC2;
    let nodes = 2;
    let cluster = Cluster::preset(preset, nodes);
    let n = cluster.num_devices();
    let model = ModelKind::Gpt2;

    // Candidate grid: every (dp, mp, pp) factorization of the cluster,
    // micro-batch counts compatible with the batch, ZeRO / recompute
    // toggles.
    let scenarios: Vec<Scenario> = candidate_grid(n, batch)
        .into_iter()
        .map(|spec| Scenario {
            model: ModelSpec::preset(model),
            batch,
            preset,
            nodes,
            spec,
        })
        .collect();

    let runner = SweepRunner::new();
    let threads = runner.effective_threads(scenarios.len());
    let t0 = std::time::Instant::now();
    let outcomes = runner.run(&scenarios);
    let search_time = t0.elapsed();
    let ranked = SweepRunner::rank(&outcomes);
    let skipped_oom = outcomes.iter().filter(|o| o.oom).count();
    let viable = ranked.iter().filter(|o| !o.oom).count();

    println!(
        "searched {} candidates ({} OOM, {} viable) in {:.2?} on {threads} threads — top 5:",
        outcomes.len(),
        skipped_oom,
        viable,
        search_time
    );
    let mut table = Table::new(&["rank", "strategy", "pred samples/s", "pred step ms"]);
    for (i, o) in ranked.iter().take(5).enumerate() {
        let r = o.report.as_ref().unwrap();
        // Infeasible candidates rank below all feasible ones but can
        // still pad the tail — mark them so the table never silently
        // recommends a strategy that cannot fit.
        let mut label = o.scenario.spec.label();
        if o.oom {
            label.push_str(" (OOM)");
        }
        table.row(vec![
            (i + 1).to_string(),
            label,
            format!("{:.1}", r.throughput),
            format!("{:.2}", r.step_ms),
        ]);
    }
    print!("{}", table.render());

    // Validate the winner on the testbed emulator.
    let graph = model.build(batch);
    let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
    // The winner is the best *feasible* candidate; an OOM candidate
    // cannot run, so there is nothing to validate if none fits.
    let Some(best) = ranked.iter().find(|o| !o.oom) else {
        println!("no feasible strategy fits this cluster's memory — nothing to validate");
        return Ok(());
    };
    let best_pred = best.report.as_ref().unwrap();
    let tree = build_strategy(&graph, best.scenario.spec)?;
    let eg = compile(&graph, &tree, &cluster)?;
    let truth = Emulator::new(&cluster, &est).simulate(&eg)?;
    let err = (best_pred.throughput - truth.throughput).abs() / truth.throughput * 100.0;
    println!(
        "\nwinner {} validated on the emulator: predicted {:.1} vs true {:.1} samples/s ({err:.2}% error)",
        best.scenario.spec.label(),
        best_pred.throughput,
        truth.throughput
    );
    // And confirm nothing in the top-5 would actually have beaten it.
    let mut best_true = (best.scenario.spec.label(), truth.throughput);
    for o in ranked.iter().take(5).skip(1) {
        let tree = build_strategy(&graph, o.scenario.spec)?;
        let eg = compile(&graph, &tree, &cluster)?;
        let t = Emulator::new(&cluster, &est).simulate(&eg)?;
        if t.throughput > best_true.1 {
            best_true = (o.scenario.spec.label(), t.throughput);
        }
    }
    println!(
        "true best among top-5 candidates: {} ({:.1} samples/s)",
        best_true.0, best_true.1
    );
    Ok(())
}
