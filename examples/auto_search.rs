//! Automated strategy **optimization** over non-uniform strategy trees
//! — one step past `strategy_search.rs`'s uniform grid ranking.
//!
//! The uniform `DP × MP × PP` sweep scores a few hundred expert-shaped
//! candidates; the paper's strategy tree can express far more (per-stage
//! degrees, moved stage boundaries, per-stage ZeRO). This example:
//!
//! 1. ranks the deduplicated uniform grid for GPT-2 on two HC2 nodes
//!    (16 GPUs) with the parallel `SweepRunner`;
//! 2. seeds a simulated-annealing `Searcher` from the grid's best
//!    candidate plus the heuristic expert points;
//! 3. anneals over the non-uniform space — re-splitting stage degrees,
//!    moving boundaries, toggling per-stage ZeRO, switching schedules
//!    and collective algorithms — under a fixed simulation budget.
//!
//! Because one chain starts at the grid optimum and the searcher's
//! scoring path is shared with the sweep, the search result can only
//! match or beat the grid — the interesting output is *how much* the
//! non-uniform moves buy on top.
//!
//! ```bash
//! cargo run --release --example auto_search
//! # equivalently: cargo run --release -- search --model gpt2 --batch 64 \
//! #               --preset HC2 --nodes 2 --budget 300 --chains 4 --seed 42
//! ```

use proteus::prelude::*;
use proteus::runtime::default_inits;
use proteus::util::table::Table;

fn main() -> proteus::Result<()> {
    let model = ModelKind::Gpt2;
    let batch = 64;
    let preset = Preset::HC2;
    let nodes = 2;
    let cluster = Cluster::preset(preset, nodes);
    let n = cluster.num_devices();
    let graph = model.build(batch);

    // --- 1. Baseline: the deduplicated uniform grid. -------------------
    let specs = dedupe_specs(&graph, candidate_grid(n, batch));
    let scenarios: Vec<Scenario> = specs
        .into_iter()
        .map(|spec| Scenario {
            model: ModelSpec::preset(model),
            batch,
            preset,
            nodes,
            spec,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let outcomes = SweepRunner::new().run(&scenarios);
    let ranked = SweepRunner::rank(&outcomes);
    let Some(grid_best) = ranked.iter().find(|o| !o.oom) else {
        println!("no feasible uniform strategy — nothing to improve on");
        return Ok(());
    };
    let grid_tput = grid_best.throughput().unwrap();
    println!(
        "uniform grid: {} candidates in {:.2?}; best {} at {:.1} samples/s",
        outcomes.len(),
        t0.elapsed(),
        grid_best.scenario.spec.label(),
        grid_tput,
    );

    // --- 2. Anneal from the grid optimum + expert seeds. ----------------
    let mut inits = vec![SearchPoint::from_uniform(&graph, grid_best.scenario.spec)?];
    inits.extend(default_inits(&graph, n, CollAlgo::Auto));
    let config = SearchConfig {
        seed: 42,
        budget: 300,
        chains: 4,
        ..SearchConfig::default()
    };
    let t1 = std::time::Instant::now();
    let result = Searcher::new(config).run(&graph, &cluster, &inits)?;
    println!(
        "\nannealed {} candidates in {:.2?} ({} template-cache hits):",
        result.evals,
        t1.elapsed(),
        result.cache_hits,
    );
    let mut table = Table::new(&["chain", "evals", "accepted", "best samples/s", "best strategy"]);
    for c in &result.chains {
        table.row(vec![
            c.chain.to_string(),
            c.evals.to_string(),
            c.accepted.to_string(),
            c.best
                .as_ref()
                .map(|e| format!("{:.1}", e.throughput))
                .unwrap_or_else(|| "-".into()),
            c.best
                .as_ref()
                .map(|e| e.label.clone())
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.render());

    // --- 3. The verdict. ------------------------------------------------
    let best = result.best.expect("seeded from a feasible point");
    let gain = (best.throughput / grid_tput - 1.0) * 100.0;
    println!(
        "\nsearch best: {} at {:.1} samples/s ({:+.2}% vs uniform grid best)",
        best.label, best.throughput, gain,
    );
    assert!(
        best.throughput >= grid_tput,
        "search is seeded at the grid optimum and can only improve"
    );
    println!("spec JSON (feed back via `proteus search --resume`):");
    println!("{}", best.point.spec.to_json().to_string_pretty());
    Ok(())
}
