//! End-to-end validation driver: the full system on the paper's full
//! workload grid.
//!
//! For every benchmark model × {S1, S2} × hardware configuration ×
//! GPU count, this driver:
//!
//!   1. builds the model graph and the strategy tree,
//!   2. compiles the distributed execution graph,
//!   3. estimates op costs through the AOT PJRT cost kernel (falling
//!      back to the analytical mirror if `make artifacts` hasn't run),
//!   4. predicts throughput with HTAE,
//!   5. measures "ground truth" on the flow-level testbed emulator,
//!   6. runs the FlexFlow-Sim baseline where its strategy space allows,
//!
//! and reports the paper's headline metric: average |prediction error|
//! of Proteus vs FlexFlow-Sim (paper: 3.0% vs 12.4%). Results feed
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example end_to_end
//! ```

use proteus::executor::calibrate;
use proteus::prelude::*;
use proteus::strategy::paper::{batch_for, s1, s2};
use proteus::util::table::Table;

fn main() -> proteus::Result<()> {
    let grid: Vec<(Preset, usize, Vec<usize>)> = vec![
        (Preset::HC1, 1, vec![1, 2, 4, 8]),
        (Preset::HC2, 4, vec![8, 16, 32]),
        (Preset::HC3, 2, vec![8, 16]),
    ];
    let mut table = Table::new(&[
        "model", "strat", "hc", "gpus", "truth sps", "htae sps", "err%", "ff err%", "oom",
    ]);
    let mut proteus_errs = Vec::new();
    let mut ff_errs = Vec::new();
    let mut ff_unsupported = 0usize;
    let mut total = 0usize;

    for (preset, nodes, gpu_counts) in &grid {
        let cluster = Cluster::preset(*preset, *nodes);
        let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
        let config = HtaeConfig {
            gamma: calibrate::default_gamma(&cluster),
            ..HtaeConfig::default()
        };
        for &m in ModelKind::all() {
            for &n in gpu_counts {
                if n > cluster.num_devices() {
                    continue;
                }
                for (sname, spec) in [("S1", s1(m, n)), ("S2", s2(m, n))] {
                    total += 1;
                    let graph = m.build(batch_for(m, n));
                    let tree = build_strategy(&graph, spec)?;
                    let eg = compile(&graph, &tree, &cluster)?;
                    let truth = Emulator::new(&cluster, &est).simulate(&eg)?;
                    let pred = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
                    let err = (pred.throughput - truth.throughput).abs() / truth.throughput
                        * 100.0;
                    proteus_errs.push(err);
                    let ff = FlexFlowSim::new(&cluster).simulate(&graph, &tree, &eg);
                    let ff_cell = match &ff {
                        Ok(f) => {
                            let e = (f.throughput - truth.throughput).abs()
                                / truth.throughput
                                * 100.0;
                            ff_errs.push(e);
                            format!("{e:.1}")
                        }
                        Err(_) => {
                            ff_unsupported += 1;
                            "✗".into()
                        }
                    };
                    table.row(vec![
                        m.name().into(),
                        sname.into(),
                        preset.name().into(),
                        n.to_string(),
                        format!("{:.1}", truth.throughput),
                        format!("{:.1}", pred.throughput),
                        format!("{err:.1}"),
                        ff_cell,
                        if truth.oom { "OOM".into() } else { "".into() },
                    ]);
                }
            }
        }
    }
    print!("{}", table.render());
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(0.0, f64::max);
    println!("\n=== headline (paper: Proteus 3.0% avg, FlexFlow-Sim 12.4% avg) ===");
    println!(
        "Proteus      avg |err| = {:.2}%   max = {:.2}%   ({} runs)",
        mean(&proteus_errs),
        max(&proteus_errs),
        proteus_errs.len()
    );
    println!(
        "FlexFlow-Sim avg |err| = {:.2}%   max = {:.2}%   ({} supported, {} unsupported of {total})",
        mean(&ff_errs),
        max(&ff_errs),
        ff_errs.len(),
        ff_unsupported
    );
    assert!(
        mean(&proteus_errs) < mean(&ff_errs),
        "Proteus must beat FlexFlow-Sim on average"
    );
    Ok(())
}
