//! Memory planning with OOM prediction: given GPT-1.5B on one HC2 node
//! (8×V100, 16 GB), find which combinations of ZeRO, recomputation, and
//! per-GPU batch size fit — the "how many machine-hours / which config
//! do I buy" workflow the paper motivates (§I) — all without touching a
//! GPU.
//!
//! ```bash
//! cargo run --release --example memory_planner
//! ```

use proteus::executor::calibrate;
use proteus::prelude::*;
use proteus::util::fmt_bytes;
use proteus::util::table::Table;

fn main() -> proteus::Result<()> {
    let cluster = Cluster::preset(Preset::HC2, 1);
    let est = OpEstimator::best_available(&cluster, "artifacts/costmodel.hlo.txt");
    let config = HtaeConfig {
        gamma: calibrate::default_gamma(&cluster),
        ..HtaeConfig::default()
    };
    println!(
        "GPT-1.5B on {} ({} GPUs × {}):",
        cluster.name,
        cluster.num_devices(),
        fmt_bytes(cluster.device.memory_bytes)
    );

    let mut table = Table::new(&[
        "per-gpu batch",
        "zero",
        "recompute",
        "peak mem",
        "fits",
        "samples/s",
    ]);
    let mut best: Option<(f64, String)> = None;
    for per_gpu in [1usize, 2, 4] {
        for (zero, recompute) in [(false, false), (true, false), (false, true), (true, true)] {
            let batch = per_gpu * 8;
            let graph = ModelKind::Gpt15B.build(batch);
            let mut spec = StrategySpec::data_parallel(8);
            spec.zero = zero;
            spec.recompute = recompute;
            let tree = build_strategy(&graph, spec)?;
            let eg = compile(&graph, &tree, &cluster)?;
            let r = Htae::with_config(&cluster, &est, config).simulate(&eg)?;
            let peak = r.peak_mem.iter().copied().max().unwrap_or(0);
            let fits = !r.oom;
            table.row(vec![
                per_gpu.to_string(),
                zero.to_string(),
                recompute.to_string(),
                fmt_bytes(peak),
                if fits { "yes".into() } else { "OOM".into() },
                if fits {
                    format!("{:.2}", r.throughput)
                } else {
                    "-".into()
                },
            ]);
            if fits {
                let label = format!("batch/gpu={per_gpu} zero={zero} recompute={recompute}");
                if best.as_ref().map(|(t, _)| r.throughput > *t).unwrap_or(true) {
                    best = Some((r.throughput, label));
                }
            }
        }
    }
    print!("{}", table.render());
    match best {
        Some((tps, label)) => {
            println!("\nbest feasible config: {label} → {tps:.2} samples/s")
        }
        None => println!("\nno feasible config on this cluster — add nodes or pipeline"),
    }
    Ok(())
}
