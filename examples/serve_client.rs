//! Drive a `proteus serve` daemon over stdin/stdout.
//!
//! Spawns the `proteus` binary as a child process, sends three NDJSON
//! requests — a simulate, the *same* simulate again, and a sweep — and
//! prints each response's cache-hit trajectory: the repeat is answered
//! from the warm template cache (hits > 0, misses = 0), and its body is
//! byte-identical to the first answer.
//!
//! ```text
//! cargo build && cargo run --example serve_client
//! ```
//!
//! Set `PROTEUS_BIN` to point at a specific binary; otherwise the
//! example looks next to its own target directory
//! (`target/<profile>/proteus`).

use std::io::{BufRead, BufReader, Write};
use std::process::{Command, Stdio};

/// Locate the `proteus` binary: `$PROTEUS_BIN`, or sibling of this
/// example's target directory.
fn proteus_bin() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("PROTEUS_BIN") {
        return Some(p.into());
    }
    // target/<profile>/examples/serve_client → target/<profile>/proteus
    let exe = std::env::current_exe().ok()?;
    let profile_dir = exe.parent()?.parent()?;
    let bin = profile_dir.join(format!("proteus{}", std::env::consts::EXE_SUFFIX));
    bin.exists().then_some(bin)
}

fn main() {
    let Some(bin) = proteus_bin() else {
        // Graceful no-op so `cargo run --example` works before `cargo
        // build` has produced the binary.
        println!("serve_client: proteus binary not found (set PROTEUS_BIN or run `cargo build` first)");
        return;
    };
    let mut child = Command::new(&bin)
        .args(["serve", "--threads", "1"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn proteus serve");

    let simulate = r#"{"id":"sim-cold","cmd":"simulate","model":"vgg19","batch":16,"preset":"HC1","nodes":1,"dp":2}"#;
    let repeat = simulate.replace("sim-cold", "sim-warm");
    let sweep = r#"{"id":"sweep","cmd":"sweep","model":"vgg19","batch":16,"preset":"HC1","nodes":1,"top":3,"threads":2}"#;

    // Write all three requests, then close stdin so the daemon drains
    // the queue and exits.
    {
        let mut stdin = child.stdin.take().expect("child stdin");
        for req in [simulate, &repeat, sweep] {
            writeln!(stdin, "{req}").expect("write request");
        }
    }

    let stdout = child.stdout.take().expect("child stdout");
    let mut n = 0usize;
    for line in BufReader::new(stdout).lines() {
        let line = line.expect("read response");
        n += 1;
        // Envelope prefix: {"id":…,"ok":…,"cache_hits":H,"cache_misses":M,…
        let field = |key: &str| -> String {
            let pat = format!("\"{key}\":");
            let rest = &line[line.find(&pat).map(|i| i + pat.len()).unwrap_or(0)..];
            rest[..rest.find([',', '}']).unwrap_or(rest.len())].to_string()
        };
        println!(
            "response {n}: id={} ok={} cache_hits={} cache_misses={} ({} bytes)",
            field("id"),
            field("ok"),
            field("cache_hits"),
            field("cache_misses"),
            line.len(),
        );
    }
    let status = child.wait().expect("wait for daemon");
    assert!(status.success(), "proteus serve exited with {status}");
    assert_eq!(n, 3, "expected one response per request");
    println!("daemon exited cleanly after {n} responses");
}
